//! Multi-head attention: exact softmax attention and Panther's
//! `RandMultiHeadAttention` (Performer FAVOR+ linear attention,
//! Choromanski et al. 2022 — the paper's [3]).
//!
//! Both forwards route every temporary through a [`MemTracker`], so the
//! Figure-3 experiment (peak forward memory vs sequence length, with "x"
//! markers where the dense implementation exceeds the device budget) is
//! measured, not modeled: the dense path materializes the `h × n × n` score
//! tensor exactly like `nn.MultiheadAttention` does, the Performer path
//! only ever holds `n × m` feature blocks and the `m × d_h` running state.

use super::module::{Cache, ForwardCtx, GradStore, Module, ParamMut, ParamRef};
use super::plan::Sketchable;
use crate::linalg::{matmul, Mat};
use crate::rng::{Philox, Rng};
use crate::util::memtrack::{MemError, MemGuard, MemTracker};

/// Shared backward tail of both attention variants: given per-head input
/// gradients already assembled into `dq`/`dk`/`dv` (n×d, in *raw
/// projection* space) and the cached input, accumulate the projection
/// gradients and return `∂loss/∂x`.
///
/// `q = x·Wq` etc. ⇒ `dWq = xᵀ·dq`, `dx = dq·Wqᵀ + dk·Wkᵀ + dv·Wvᵀ`
/// (the output-projection term is added by the caller).
fn attn_proj_backward(
    w: &AttnWeights,
    grads: &mut GradStore,
    x: &Mat,
    dq: &Mat,
    dk: &Mat,
    dv: &Mat,
) -> Mat {
    grads.accum("wq", 1.0, crate::linalg::matmul_tn(x, dq).data());
    grads.accum("wk", 1.0, crate::linalg::matmul_tn(x, dk).data());
    grads.accum("wv", 1.0, crate::linalg::matmul_tn(x, dv).data());
    let mut dx = crate::linalg::matmul_nt(dq, &w.wq);
    dx.axpy(1.0, &crate::linalg::matmul_nt(dk, &w.wk));
    dx.axpy(1.0, &crate::linalg::matmul_nt(dv, &w.wv));
    dx
}

/// Named views of the shared Q/K/V/output projections (both attention
/// variants expose identical parameter state — the Performer's random
/// features are fixed, not trained, so they are deliberately absent).
fn attn_params(w: &AttnWeights) -> Vec<(String, ParamRef<'_>)> {
    vec![
        ("wq".to_string(), ParamRef::Mat(&w.wq)),
        ("wk".to_string(), ParamRef::Mat(&w.wk)),
        ("wv".to_string(), ParamRef::Mat(&w.wv)),
        ("wo".to_string(), ParamRef::Mat(&w.wo)),
    ]
}

fn attn_params_mut(w: &mut AttnWeights) -> Vec<(String, ParamMut<'_>)> {
    vec![
        ("wq".to_string(), ParamMut::Mat(&mut w.wq)),
        ("wk".to_string(), ParamMut::Mat(&mut w.wk)),
        ("wv".to_string(), ParamMut::Mat(&mut w.wv)),
        ("wo".to_string(), ParamMut::Mat(&mut w.wo)),
    ]
}

/// Random-feature kernel for the Performer (the paper benchmarks both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// FAVOR+ positive features for the softmax kernel.
    Softmax,
    /// ReLU features.
    Relu,
}

/// Shared per-head projection weights (Q, K, V, output), so the dense and
/// random variants compare with identical parameter state.
#[derive(Clone, Debug)]
pub struct AttnWeights {
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub embed_dim: usize,
    pub num_heads: usize,
}

impl AttnWeights {
    pub fn random<R: Rng>(embed_dim: usize, num_heads: usize, rng: &mut R) -> Self {
        assert_eq!(embed_dim % num_heads, 0, "embed_dim must divide num_heads");
        let s = (1.0 / embed_dim as f32).sqrt();
        AttnWeights {
            wq: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            wk: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            wv: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            wo: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            embed_dim,
            num_heads,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.embed_dim / self.num_heads
    }
}

/// Exact softmax multi-head attention (the `nn.MultiheadAttention`
/// baseline). Forward runs through the unified [`Module`] API.
#[derive(Clone)]
pub struct MultiHeadAttention {
    pub weights: AttnWeights,
    grads: GradStore,
}

/// Activation cache of [`MultiHeadAttention::forward_train`]: input, raw
/// projections, per-head softmax rows, and the pre-`Wo` head concat —
/// the same `h·n·n` score memory the forward materializes.
struct MhaCache {
    x: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    /// Per-head softmax probability matrices (n×n).
    probs: Vec<Mat>,
    /// Head outputs concatenated (n×d), before the output projection.
    concat: Mat,
    /// The forward's allocation guards — moved here instead of released,
    /// so the cached activations stay charged against the tracker for
    /// the cache's lifetime.
    _guards: Vec<MemGuard>,
}

impl MultiHeadAttention {
    pub fn new(weights: AttnWeights) -> Self {
        MultiHeadAttention {
            weights,
            grads: GradStore::default(),
        }
    }

    /// Self-attention forward on `x: n × d`, tracking every temporary in
    /// `mem`. Returns `n × d` or a budget error (the Fig. 3 "x"). With
    /// `want_cache`, also returns the activations backward needs.
    fn forward_with(
        &self,
        x: &Mat,
        mem: &MemTracker,
        want_cache: bool,
    ) -> Result<(Mat, Option<MhaCache>), MemError> {
        let w = &self.weights;
        let n = x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        assert_eq!(x.cols(), d);
        // Projections (each n×d). On the inference path the guards release
        // on return; a training forward moves them into the cache so the
        // retained activations stay accounted until backward.
        let gq = mem.alloc((n * d * 4) as u64)?;
        let q = matmul(x, &w.wq);
        let gk = mem.alloc((n * d * 4) as u64)?;
        let k = matmul(x, &w.wk);
        let gv = mem.alloc((n * d * 4) as u64)?;
        let v = matmul(x, &w.wv);
        let mut out = Mat::zeros(n, d);
        let go = mem.alloc((n * d * 4) as u64)?;
        let scale = 1.0 / (dh as f32).sqrt();
        // The dense score matrix for ALL heads is what blows memory on GPUs;
        // PyTorch materializes (h, n, n) at once — we account the same.
        let gscores = mem.alloc((h * n * n * 4) as u64)?;
        let mut probs = Vec::with_capacity(if want_cache { h } else { 0 });
        for head in 0..h {
            let c0 = head * dh;
            let qh = q.slice(0, n, c0, c0 + dh);
            let kh = k.slice(0, n, c0, c0 + dh);
            let vh = v.slice(0, n, c0, c0 + dh);
            // scores = Qh·Khᵀ · scale, then row-softmax.
            let mut scores = crate::linalg::matmul_nt(&qh, &kh);
            for i in 0..n {
                let row = scores.row_mut(i);
                let mut mx = f32::NEG_INFINITY;
                for v in row.iter_mut() {
                    *v *= scale;
                    mx = mx.max(*v);
                }
                let mut sum = 0f32;
                for v in row.iter_mut() {
                    *v = (*v - mx).exp();
                    sum += *v;
                }
                for v in row.iter_mut() {
                    *v /= sum;
                }
            }
            let oh = matmul(&scores, &vh); // n × dh
            for i in 0..n {
                out.row_mut(i)[c0..c0 + dh].copy_from_slice(oh.row(i));
            }
            if want_cache {
                probs.push(scores);
            }
        }
        let y = matmul(&out, &w.wo);
        let cache = want_cache.then(|| MhaCache {
            x: x.clone(),
            q,
            k,
            v,
            probs,
            concat: out,
            _guards: vec![gq, gk, gv, go, gscores],
        });
        Ok((y, cache))
    }
}

impl Module for MultiHeadAttention {
    fn type_name(&self) -> &'static str {
        "MultiheadAttention"
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        Ok(self.forward_with(x, ctx.mem(), false)?.0)
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let (y, cache) = self.forward_with(x, ctx.mem(), true)?;
        Ok((y, Cache::new(cache.expect("cache requested"))))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &MhaCache = cache.downcast::<MhaCache>()?;
        let w = &self.weights;
        let n = c.x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        anyhow::ensure!(
            g.shape() == (n, d),
            "grad_out shape {:?} vs expected ({n}, {d})",
            g.shape()
        );
        // Dominant transients: dq/dk/dv/dconcat (n×d each) plus one n×n
        // score gradient per head alive at a time.
        let _act = ctx.mem().alloc(((4 * n * d + n * n) * 4) as u64)?;
        let scale = 1.0 / (dh as f32).sqrt();
        // Output projection: y = concat·Wo.
        let dwo = crate::linalg::matmul_tn(&c.concat, g); // d×d
        let dconcat = crate::linalg::matmul_nt(g, &w.wo); // n×d
        let mut dq = Mat::zeros(n, d);
        let mut dk = Mat::zeros(n, d);
        let mut dv = Mat::zeros(n, d);
        for head in 0..h {
            let c0 = head * dh;
            let qh = c.q.slice(0, n, c0, c0 + dh);
            let kh = c.k.slice(0, n, c0, c0 + dh);
            let vh = c.v.slice(0, n, c0, c0 + dh);
            let p = &c.probs[head];
            let doh = dconcat.slice(0, n, c0, c0 + dh); // n×dh
            // oh = P·Vh ⇒ dVh = Pᵀ·doh, dP = doh·Vhᵀ.
            let dvh = crate::linalg::matmul_tn(p, &doh);
            let mut ds = crate::linalg::matmul_nt(&doh, &vh); // dP, reused for dS
            // Row-softmax backward: dS_ij = P_ij·(dP_ij − Σ_k dP_ik·P_ik).
            for i in 0..n {
                let dot: f64 = ds
                    .row(i)
                    .iter()
                    .zip(p.row(i))
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum();
                for (sv, &pv) in ds.row_mut(i).iter_mut().zip(p.row(i)) {
                    *sv = pv * (*sv - dot as f32);
                }
            }
            // S = scale·Qh·Khᵀ ⇒ dQh = scale·dS·Kh, dKh = scale·dSᵀ·Qh.
            let dqh = matmul(&ds, &kh).scale(scale);
            let dkh = crate::linalg::matmul_tn(&ds, &qh).scale(scale);
            for i in 0..n {
                dq.row_mut(i)[c0..c0 + dh].copy_from_slice(dqh.row(i));
                dk.row_mut(i)[c0..c0 + dh].copy_from_slice(dkh.row(i));
                dv.row_mut(i)[c0..c0 + dh].copy_from_slice(dvh.row(i));
            }
        }
        let dx = attn_proj_backward(&self.weights, &mut self.grads, &c.x, &dq, &dk, &dv);
        self.grads.accum("wo", 1.0, dwo.data());
        Ok(dx)
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        attn_params(&self.weights)
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        attn_params_mut(&mut self.weights)
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn as_sketchable(&self) -> Option<&dyn Sketchable> {
        Some(self)
    }
}

/// Performer-style random-feature attention — Panther's
/// `RandMultiHeadAttention`. Forward runs through the unified [`Module`]
/// API.
#[derive(Clone)]
pub struct RandMultiHeadAttention {
    pub weights: AttnWeights,
    pub num_features: usize,
    pub kernel: KernelKind,
    /// Per-head random projection `ω: d_h × m` (orthogonal-ish gaussian).
    features: Vec<Mat>,
    grads: GradStore,
}

/// Per-head slice of [`RandMhaCache`]: everything the linear-attention
/// backward reuses — all `O(n·m + m·d_h)`, never `n×n`.
struct PerfHead {
    /// Scaled Q/K head slices (the feature-map inputs) and the V slice.
    qh: Mat,
    kh: Mat,
    vh: Mat,
    phi_q: Mat,
    phi_k: Mat,
    /// `φ(K)ᵀ·V` (m × d_h).
    kv: Mat,
    /// Normalizer `φ(K)ᵀ·1` (length m).
    z: Vec<f32>,
    /// Numerator `φ(Q)·kv` (n × d_h).
    num: Mat,
    /// Pre-clamp denominators `φ(Q)_i·z` — backward zeroes the normalizer
    /// gradient where the forward's `max(·, 1e-9)` clamp was active.
    den_raw: Vec<f32>,
}

/// Activation cache of [`RandMultiHeadAttention::forward_train`].
struct RandMhaCache {
    x: Mat,
    /// Head outputs concatenated (n×d), before the output projection.
    concat: Mat,
    heads: Vec<PerfHead>,
    /// The forward's allocation guards (projections + per-head state) —
    /// kept charged for the cache's lifetime.
    _guards: Vec<MemGuard>,
}

impl RandMultiHeadAttention {
    pub fn new(weights: AttnWeights, num_features: usize, kernel: KernelKind, seed: u64) -> Self {
        let dh = weights.head_dim();
        let mut rng = Philox::seeded(seed);
        let features = (0..weights.num_heads)
            .map(|_| Mat::randn(dh, num_features, &mut rng))
            .collect();
        RandMultiHeadAttention {
            weights,
            num_features,
            kernel,
            features,
            grads: GradStore::default(),
        }
    }

    /// FAVOR+ feature map. Softmax: `φ(x) = exp(ωᵀx − ‖x‖²/2 − c)/√m`
    /// (positive, with a *scalar* stabilizer `c` shared by all rows — a
    /// per-row stabilizer would reweight keys and bias the attention
    /// estimate); ReLU: `max(ωᵀx, 0)/√m`.
    fn feature_map(&self, xh: &Mat, head: usize) -> Mat {
        self.feature_map_with_stab(xh, head, None)
    }

    /// Feature map with an explicit stabilizer. `None` = the block's global
    /// max (batch path). Streaming passes `Some(0.0)`: the stabilizer must
    /// be *constant across time steps* or the accumulated KV state mixes
    /// inconsistently-scaled features.
    fn feature_map_with_stab(&self, xh: &Mat, head: usize, stab: Option<f32>) -> Mat {
        let m = self.num_features;
        let proj = matmul(xh, &self.features[head]); // n × m
        let mut phi = Mat::zeros(xh.rows(), m);
        let scale = 1.0 / (m as f32).sqrt();
        match self.kernel {
            KernelKind::Softmax => {
                let mx = stab.unwrap_or_else(|| {
                    proj.data()
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max)
                });
                for i in 0..xh.rows() {
                    let sq: f32 = xh.row(i).iter().map(|&v| v * v).sum::<f32>() / 2.0;
                    let prow = proj.row(i);
                    let out = phi.row_mut(i);
                    for (o, &p) in out.iter_mut().zip(prow) {
                        *o = (p - sq - mx).exp() * scale;
                    }
                }
            }
            KernelKind::Relu => {
                for i in 0..xh.rows() {
                    let prow = proj.row(i);
                    let out = phi.row_mut(i);
                    for (o, &p) in out.iter_mut().zip(prow) {
                        *o = p.max(0.0) * scale;
                    }
                }
            }
        }
        phi
    }

    /// Linear-attention forward: `out = φ(Q)·(φ(K)ᵀV) / (φ(Q)·φ(K)ᵀ1)`.
    /// Never materializes an n×n matrix — peak extra memory is
    /// `O(n·m + m·d_h)` per head. With `want_cache`, the per-head
    /// temporaries are kept for backward instead of released.
    fn forward_with(
        &self,
        x: &Mat,
        mem: &MemTracker,
        want_cache: bool,
    ) -> Result<(Mat, Option<RandMhaCache>), MemError> {
        let w = &self.weights;
        let n = x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        let m = self.num_features;
        assert_eq!(x.cols(), d);
        let gq = mem.alloc((n * d * 4) as u64)?;
        let q = matmul(x, &w.wq);
        let gk = mem.alloc((n * d * 4) as u64)?;
        let k = matmul(x, &w.wk);
        let gv = mem.alloc((n * d * 4) as u64)?;
        let v = matmul(x, &w.wv);
        let mut out = Mat::zeros(n, d);
        let go = mem.alloc((n * d * 4) as u64)?;
        // Per-head temporaries: φ(Q), φ(K) (n×m each), KV state (m×dh),
        // normalizer (m). Released before the next head on the inference
        // path; a training forward keeps every guard in the cache so the
        // retained per-head state stays accounted until backward.
        let scale = 1.0 / (dh as f32).sqrt();
        let mut heads = Vec::with_capacity(if want_cache { h } else { 0 });
        let mut guards = vec![gq, gk, gv, go];
        for head in 0..h {
            let ghead = mem.alloc(((2 * n * m + m * dh + m) * 4) as u64)?;
            if want_cache {
                guards.push(ghead);
            }
            let c0 = head * dh;
            let qh = q.slice(0, n, c0, c0 + dh).scale(scale);
            let kh = k.slice(0, n, c0, c0 + dh).scale(scale);
            let vh = v.slice(0, n, c0, c0 + dh);
            let phi_q = self.feature_map(&qh, head); // n × m
            let phi_k = self.feature_map(&kh, head); // n × m
            // KV state: φ(K)ᵀ·V (m × dh) — the O(1)-in-n state.
            let kv = crate::linalg::matmul_tn(&phi_k, &vh);
            // Normalizer: z = φ(K)ᵀ·1 (length m).
            let mut z = vec![0f32; m];
            for i in 0..n {
                for (zj, &pj) in z.iter_mut().zip(phi_k.row(i)) {
                    *zj += pj;
                }
            }
            let num = matmul(&phi_q, &kv); // n × dh
            let mut den_raw = vec![0f32; n];
            for i in 0..n {
                let dot: f32 = phi_q
                    .row(i)
                    .iter()
                    .zip(&z)
                    .map(|(&a, &b)| a * b)
                    .sum::<f32>();
                den_raw[i] = dot;
                let denom = dot.max(1e-9);
                let orow = &mut out.row_mut(i)[c0..c0 + dh];
                for (o, &nv) in orow.iter_mut().zip(num.row(i)) {
                    *o = nv / denom;
                }
            }
            if want_cache {
                heads.push(PerfHead {
                    qh,
                    kh,
                    vh,
                    phi_q,
                    phi_k,
                    kv,
                    z,
                    num,
                    den_raw,
                });
            }
        }
        let y = matmul(&out, &w.wo);
        let cache = want_cache.then(|| RandMhaCache {
            x: x.clone(),
            concat: out,
            heads,
            _guards: guards,
        });
        Ok((y, cache))
    }

    /// Backward through the FAVOR+ feature map: given `∂loss/∂φ` and the
    /// cached `φ` for the (scaled) head input `xh`, return `∂loss/∂xh`.
    ///
    /// Softmax features `φ = exp(ωᵀx − ‖x‖²/2 − c)/√m`: with `e = dφ⊙φ`,
    /// `dx = e·ωᵀ − rowsum(e)·x`. The stabilizer `c` is treated as a
    /// constant: the normalized attention output is exactly invariant to
    /// it (it rescales numerator and denominator identically), so its true
    /// gradient contribution is zero. ReLU features: the gradient passes
    /// `ω` where `φ > 0`.
    fn feature_map_backward(&self, dphi: &Mat, phi: &Mat, xh: &Mat, head: usize) -> Mat {
        let m = self.num_features;
        let s = 1.0 / (m as f32).sqrt();
        let mut e = Mat::zeros(dphi.rows(), m);
        match self.kernel {
            KernelKind::Softmax => {
                for i in 0..e.rows() {
                    let (dr, pr) = (dphi.row(i), phi.row(i));
                    for (j, ev) in e.row_mut(i).iter_mut().enumerate() {
                        *ev = dr[j] * pr[j];
                    }
                }
                let mut dxh = crate::linalg::matmul_nt(&e, &self.features[head]);
                for i in 0..dxh.rows() {
                    let rs: f32 = e.row(i).iter().sum();
                    for (dv, &xv) in dxh.row_mut(i).iter_mut().zip(xh.row(i)) {
                        *dv -= rs * xv;
                    }
                }
                dxh
            }
            KernelKind::Relu => {
                for i in 0..e.rows() {
                    let (dr, pr) = (dphi.row(i), phi.row(i));
                    for (j, ev) in e.row_mut(i).iter_mut().enumerate() {
                        *ev = if pr[j] > 0.0 { dr[j] * s } else { 0.0 };
                    }
                }
                crate::linalg::matmul_nt(&e, &self.features[head])
            }
        }
    }

    /// Extra parameters vs dense attention: the random features are fixed
    /// (not trained), so the parameter count is identical to dense MHA.
    pub fn feature_state_bytes(&self) -> u64 {
        (self.weights.num_heads * self.weights.head_dim() * self.num_features * 4) as u64
    }

    /// Start an autoregressive decode session. Performer's linear attention
    /// admits O(1)-per-token causal decoding: the per-head running state is
    /// just `φ(K)ᵀV (m × d_h)` plus the normalizer `φ(K)ᵀ1 (m)` — constant
    /// in sequence length, unlike a softmax KV cache which grows O(n).
    pub fn start_stream(&self) -> PerformerStream<'_> {
        let h = self.weights.num_heads;
        let dh = self.weights.head_dim();
        let m = self.num_features;
        PerformerStream {
            attn: self,
            kv: vec![Mat::zeros(m, dh); h],
            z: vec![vec![0f32; m]; h],
            tokens_seen: 0,
        }
    }
}

impl Module for RandMultiHeadAttention {
    fn type_name(&self) -> &'static str {
        "RandMultiheadAttention"
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        Ok(self.forward_with(x, ctx.mem(), false)?.0)
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let (y, cache) = self.forward_with(x, ctx.mem(), true)?;
        Ok((y, Cache::new(cache.expect("cache requested"))))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &RandMhaCache = cache.downcast::<RandMhaCache>()?;
        let w = &self.weights;
        let n = c.x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        let m = self.num_features;
        anyhow::ensure!(
            g.shape() == (n, d),
            "grad_out shape {:?} vs expected ({n}, {d})",
            g.shape()
        );
        anyhow::ensure!(c.heads.len() == h, "cache head count mismatch");
        // Dominant transients: dq/dk/dv/dconcat (n×d each) plus per-head
        // dφ matrices (2·n×m) — still linear in n, like the forward.
        let _act = ctx.mem().alloc(((4 * n * d + 2 * n * m) * 4) as u64)?;
        let scale = 1.0 / (dh as f32).sqrt();
        // Output projection: y = concat·Wo.
        let dwo = crate::linalg::matmul_tn(&c.concat, g); // d×d
        let dconcat = crate::linalg::matmul_nt(g, &w.wo); // n×d
        let mut dq = Mat::zeros(n, d);
        let mut dk = Mat::zeros(n, d);
        let mut dv = Mat::zeros(n, d);
        for head in 0..h {
            let hc = &c.heads[head];
            let c0 = head * dh;
            let doh = dconcat.slice(0, n, c0, c0 + dh); // n×dh
            // out_i = num_i / den_i with den = max(φq_i·z, 1e-9):
            //   d_num_i = doh_i/den_i,
            //   d_den_i = −(doh_i·num_i)/den_i²  (zero where the clamp hit).
            let mut d_num = Mat::zeros(n, dh);
            let mut d_den = vec![0f32; n];
            for i in 0..n {
                let den = hc.den_raw[i].max(1e-9);
                for (dnv, &gv) in d_num.row_mut(i).iter_mut().zip(doh.row(i)) {
                    *dnv = gv / den;
                }
                if hc.den_raw[i] > 1e-9 {
                    let gn: f64 = doh
                        .row(i)
                        .iter()
                        .zip(hc.num.row(i))
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum();
                    d_den[i] = -(gn / (den as f64 * den as f64)) as f32;
                }
            }
            // num = φq·kv, den = φq·z:
            //   dφq = d_num·kvᵀ + d_den⊗z,  d_kv = φqᵀ·d_num,  dz = φqᵀ·d_den.
            let mut dphi_q = crate::linalg::matmul_nt(&d_num, &hc.kv); // n×m
            for i in 0..n {
                let dd = d_den[i];
                for (pv, &zv) in dphi_q.row_mut(i).iter_mut().zip(&hc.z) {
                    *pv += dd * zv;
                }
            }
            let d_kv = crate::linalg::matmul_tn(&hc.phi_q, &d_num); // m×dh
            let dz = hc.phi_q.matvec_t(&d_den); // m
            // kv = φkᵀ·vh, z = φkᵀ·1:
            //   dφk = vh·d_kvᵀ + 1⊗dz,  dvh = φk·d_kv.
            let mut dphi_k = crate::linalg::matmul_nt(&hc.vh, &d_kv); // n×m
            for i in 0..n {
                for (pv, &zv) in dphi_k.row_mut(i).iter_mut().zip(&dz) {
                    *pv += zv;
                }
            }
            let dvh = matmul(&hc.phi_k, &d_kv); // n×dh
            // Through the (fixed) random-feature maps to the scaled slices,
            // then undo the 1/√dh scaling back to raw projection space.
            let dqh = self.feature_map_backward(&dphi_q, &hc.phi_q, &hc.qh, head);
            let dkh = self.feature_map_backward(&dphi_k, &hc.phi_k, &hc.kh, head);
            for i in 0..n {
                for (slot, &v) in dq.row_mut(i)[c0..c0 + dh].iter_mut().zip(dqh.row(i)) {
                    *slot = v * scale;
                }
                for (slot, &v) in dk.row_mut(i)[c0..c0 + dh].iter_mut().zip(dkh.row(i)) {
                    *slot = v * scale;
                }
                dv.row_mut(i)[c0..c0 + dh].copy_from_slice(dvh.row(i));
            }
        }
        let dx = attn_proj_backward(&self.weights, &mut self.grads, &c.x, &dq, &dk, &dv);
        self.grads.accum("wo", 1.0, dwo.data());
        Ok(dx)
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        attn_params(&self.weights)
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        attn_params_mut(&mut self.weights)
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}

/// Streaming decode state for [`RandMultiHeadAttention`].
pub struct PerformerStream<'a> {
    attn: &'a RandMultiHeadAttention,
    /// Per-head running `φ(K)ᵀV` (m × d_h).
    kv: Vec<Mat>,
    /// Per-head running normalizer `φ(K)ᵀ1` (m).
    z: Vec<Vec<f32>>,
    tokens_seen: usize,
}

impl PerformerStream<'_> {
    /// Number of tokens absorbed so far.
    pub fn len(&self) -> usize {
        self.tokens_seen
    }

    pub fn is_empty(&self) -> bool {
        self.tokens_seen == 0
    }

    /// State size in bytes — constant in sequence length.
    pub fn state_bytes(&self) -> u64 {
        let m = self.attn.num_features as u64;
        let dh = self.attn.weights.head_dim() as u64;
        let h = self.attn.weights.num_heads as u64;
        h * (m * dh + m) * 4
    }

    /// Feed one token embedding `x_t (d,)`; returns the causal attention
    /// output for this position (attending to all tokens fed so far,
    /// including this one).
    pub fn step(&mut self, x_t: &[f32]) -> Vec<f32> {
        let w = &self.attn.weights;
        let d = w.embed_dim;
        assert_eq!(x_t.len(), d);
        let h = w.num_heads;
        let dh = w.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let x = Mat::from_vec(1, d, x_t.to_vec());
        let q = matmul(&x, &w.wq);
        let k = matmul(&x, &w.wk);
        let v = matmul(&x, &w.wv);
        let mut out = vec![0f32; d];
        for head in 0..h {
            let c0 = head * dh;
            let qh = Mat::from_vec(1, dh, q.row(0)[c0..c0 + dh].to_vec()).scale(scale);
            let kh = Mat::from_vec(1, dh, k.row(0)[c0..c0 + dh].to_vec()).scale(scale);
            let vh = &v.row(0)[c0..c0 + dh];
            let phi_q = self.attn.feature_map_with_stab(&qh, head, Some(0.0)); // 1 × m
            let phi_k = self.attn.feature_map_with_stab(&kh, head, Some(0.0)); // 1 × m
            // State update: kv += φ(k)ᵀ·v ; z += φ(k).
            let kv = &mut self.kv[head];
            for (j, &pk) in phi_k.row(0).iter().enumerate() {
                self.z[head][j] += pk;
                let row = kv.row_mut(j);
                for (dst, &vv) in row.iter_mut().zip(vh) {
                    *dst += pk * vv;
                }
            }
            // Output: φ(q)·kv / (φ(q)·z).
            let pq = phi_q.row(0);
            let denom: f32 = pq
                .iter()
                .zip(&self.z[head])
                .map(|(&a, &b)| a * b)
                .sum::<f32>()
                .max(1e-9);
            let orow = &mut out[c0..c0 + dh];
            for (j, &pqj) in pq.iter().enumerate() {
                let kvrow = self.kv[head].row(j);
                for (o, &s) in orow.iter_mut().zip(kvrow) {
                    *o += pqj * s;
                }
            }
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
        self.tokens_seen += 1;
        // Output projection.
        matmul(&Mat::from_vec(1, d, out), &w.wo).into_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_error;
    use crate::rng::Philox;

    #[test]
    fn dense_attention_rows_are_convex_combinations() {
        // With Wv = I and softmax rows summing to 1, each head output row
        // lies in the convex hull of V rows — check value bounds instead:
        // output of softmax(scores)·V has entries ≤ max|V|.
        let mut rng = Philox::seeded(131);
        let w = AttnWeights::random(16, 4, &mut rng);
        let mha = MultiHeadAttention::new(w);
        let x = Mat::randn(12, 16, &mut rng);
        let ctx = ForwardCtx::new();
        let y = mha.forward(&x, &ctx).unwrap();
        assert_eq!(y.shape(), (12, 16));
        assert!(ctx.mem().peak_bytes() > 0);
        assert_eq!(ctx.mem().live_bytes(), 0, "all temporaries released");
    }

    #[test]
    fn performer_approximates_dense_softmax() {
        // With plenty of random features the Performer output should land
        // near exact attention (loose tolerance — it's a Monte-Carlo method).
        let mut rng = Philox::seeded(132);
        let w = AttnWeights::random(8, 1, &mut rng);
        let x = Mat::randn(10, 8, &mut rng).scale(0.3); // small norms: RF approx is accurate
        let dense = MultiHeadAttention::new(w.clone());
        let ctx = ForwardCtx::new();
        let y_exact = dense.forward(&x, &ctx).unwrap();
        let perf = RandMultiHeadAttention::new(w, 2048, KernelKind::Softmax, 5);
        let y_rand = perf.forward(&x, &ctx).unwrap();
        let err = rel_error(&y_rand, &y_exact);
        assert!(err < 0.5, "performer deviates: rel {err}");
    }

    #[test]
    fn performer_memory_linear_dense_quadratic() {
        let mut rng = Philox::seeded(133);
        let w = AttnWeights::random(32, 4, &mut rng);
        let measure_dense = |n: usize| {
            let x = Mat::randn(n, 32, &mut Philox::seeded(1));
            let ctx = ForwardCtx::new();
            MultiHeadAttention::new(w.clone()).forward(&x, &ctx).unwrap();
            ctx.mem().peak_bytes()
        };
        let measure_perf = |n: usize| {
            let x = Mat::randn(n, 32, &mut Philox::seeded(1));
            let ctx = ForwardCtx::new();
            RandMultiHeadAttention::new(w.clone(), 16, KernelKind::Softmax, 2)
                .forward(&x, &ctx)
                .unwrap();
            ctx.mem().peak_bytes()
        };
        // Dense grows ~4× when n doubles; performer ~2×.
        let (d1, d2) = (measure_dense(64), measure_dense(128));
        let (p1, p2) = (measure_perf(64), measure_perf(128));
        let dense_ratio = d2 as f64 / d1 as f64;
        let perf_ratio = p2 as f64 / p1 as f64;
        assert!(dense_ratio > 3.0, "dense ratio {dense_ratio}");
        assert!(perf_ratio < 2.5, "performer ratio {perf_ratio}");
    }

    #[test]
    fn dense_oom_performer_survives() {
        // A budget that the quadratic path exceeds but the linear one fits —
        // the Figure-3 "x" marker scenario.
        let mut rng = Philox::seeded(134);
        let w = AttnWeights::random(32, 8, &mut rng);
        let n = 256;
        let x = Mat::randn(n, 32, &mut rng);
        let budget = 2 * 1024 * 1024; // 2 MiB
        let ctx_d = ForwardCtx::with_budget(budget);
        let dense_res = MultiHeadAttention::new(w.clone()).forward(&x, &ctx_d);
        assert!(dense_res.is_err(), "dense should exceed 2 MiB at n=256,h=8");
        let ctx_p = ForwardCtx::with_budget(budget);
        let perf_res =
            RandMultiHeadAttention::new(w, 32, KernelKind::Softmax, 3).forward(&x, &ctx_p);
        assert!(perf_res.is_ok(), "performer must fit the same budget");
    }

    #[test]
    fn streaming_matches_causal_reference() {
        // The t-th streamed output must equal linear attention computed
        // over the prefix 0..=t with the same (stab=0) feature map.
        let mut rng = Philox::seeded(136);
        let (d, h, m, n) = (16usize, 2usize, 32usize, 10usize);
        let w = AttnWeights::random(d, h, &mut rng);
        let attn = RandMultiHeadAttention::new(w.clone(), m, KernelKind::Softmax, 11);
        let x = Mat::randn(n, d, &mut rng).scale(0.4);
        let mut stream = attn.start_stream();
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        let q = crate::linalg::matmul(&x, &w.wq);
        let k = crate::linalg::matmul(&x, &w.wk);
        let v = crate::linalg::matmul(&x, &w.wv);
        for t in 0..n {
            let got = stream.step(x.row(t));
            // Reference: per head, φ over prefix with stab 0.
            let mut pre = Mat::zeros(1, d);
            for head in 0..h {
                let c0 = head * dh;
                let qh = Mat::from_vec(1, dh, q.row(t)[c0..c0 + dh].to_vec()).scale(scale);
                let pq = attn.feature_map_with_stab(&qh, head, Some(0.0));
                let mut num = vec![0f64; dh];
                let mut den = 0f64;
                for s in 0..=t {
                    let kh =
                        Mat::from_vec(1, dh, k.row(s)[c0..c0 + dh].to_vec()).scale(scale);
                    let pk = attn.feature_map_with_stab(&kh, head, Some(0.0));
                    let dot: f64 = pq
                        .row(0)
                        .iter()
                        .zip(pk.row(0))
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum();
                    den += dot;
                    for (nv, &vv) in num.iter_mut().zip(&v.row(s)[c0..c0 + dh]) {
                        *nv += dot * vv as f64;
                    }
                }
                for (j, nv) in num.iter().enumerate() {
                    pre.set(0, c0 + j, (nv / den.max(1e-12)) as f32);
                }
            }
            let want = crate::linalg::matmul(&pre, &w.wo);
            for (a, b) in got.iter().zip(want.row(0)) {
                assert!(
                    (a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "t={t}: {a} vs {b}"
                );
            }
        }
        assert_eq!(stream.len(), n);
    }

    #[test]
    fn streaming_state_is_constant_size() {
        let mut rng = Philox::seeded(137);
        let w = AttnWeights::random(32, 4, &mut rng);
        let attn = RandMultiHeadAttention::new(w, 64, KernelKind::Relu, 2);
        let mut stream = attn.start_stream();
        let s0 = stream.state_bytes();
        let x = Mat::randn(100, 32, &mut rng);
        for t in 0..100 {
            stream.step(x.row(t));
        }
        assert_eq!(stream.state_bytes(), s0, "state must not grow with n");
        assert_eq!(stream.len(), 100);
    }

    #[test]
    fn relu_kernel_runs() {
        let mut rng = Philox::seeded(135);
        let w = AttnWeights::random(16, 2, &mut rng);
        let x = Mat::randn(20, 16, &mut rng);
        let ctx = ForwardCtx::new();
        let y = RandMultiHeadAttention::new(w, 24, KernelKind::Relu, 7)
            .forward(&x, &ctx)
            .unwrap();
        assert_eq!(y.shape(), (20, 16));
        assert!(y.data().iter().all(|v| v.is_finite()));
    }
}
