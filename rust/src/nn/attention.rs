//! Multi-head attention: exact softmax attention and Panther's
//! `RandMultiHeadAttention` (Performer FAVOR+ linear attention,
//! Choromanski et al. 2022 — the paper's [3]).
//!
//! Both forwards route every temporary through a
//! [`MemTracker`](crate::util::memtrack::MemTracker), so the
//! Figure-3 experiment (peak forward memory vs sequence length, with "x"
//! markers where the dense implementation exceeds the device budget) is
//! measured, not modeled: the dense path materializes the `h × n × n` score
//! tensor exactly like `nn.MultiheadAttention` does, the Performer path
//! only ever holds `O(h·(n·m + m·d_h))` feature/state blocks.
//!
//! **Per-head math is batched.** The per-head products of both variants —
//! dense scores `Q_h·K_hᵀ`, `P_h·V_h`, the Performer's feature projections
//! and `φ(K)ᵀV`/`φ(Q)·KV` chain, and the whole backward dP/dS/dQ/dK/dV
//! chain — run as *one* [`crate::linalg::gemm_batch`] call per stage over
//! strided head views (`Mat::view().col_range(..)`, `Mat::col_bands_mut`)
//! instead of h sequential matmuls, so head-level parallelism and GEMM
//! panel reuse compose and no head slice is ever copied. Scratch blocks
//! (score matrices, feature maps, projection-space gradients) come from
//! the shared [`Workspace`] arena in [`ForwardCtx`], so steady-state
//! inference forwards and backward's transients allocate nothing on the
//! hot path (training forwards detach their buffers into the activation
//! cache, which owns — and eventually frees — them).
//!
//! **Both variants are sequence-aware.** When the [`ForwardCtx`] carries a
//! [`SeqBatch`](super::module::SeqBatch), every cross-row product is
//! restricted to one sequence's rows via exact-length `row_range` views:
//! a softmax row only ever sees its own sequence's keys, and the FAVOR+
//! `φ(K)ᵀV`/normalizer sums only run over valid positions — pad rows get
//! *structurally* zero attention weight (no −∞ biasing, no epsilon leak)
//! and zero output. With no `SeqBatch` (or one full-length sequence) the
//! per-sequence views span every row and the exact same batched products
//! execute, so the masked path is bitwise-identical to the unmasked one.
//!
//! **The dense training backward is tiled and recomputing.** The forward
//! caches only per-row softmax statistics (max, exp-sum) instead of the
//! `h·n×n` probability tensor; backward reconstructs probabilities one
//! `n×T` key tile at a time from cached Q/K and the stats, using the
//! row-dot identity `Σ_j dP_ij·P_ij = Σ_c dO_ic·O_ic` (valid because
//! `O = P·V`) to finish the softmax backward without a second pass. Peak
//! backward activation is O(h·n·T), not O(h·n²) — same asymptotics as
//! FlashAttention's backward, built from the same `gemm_batch` stages as
//! the forward.

use super::module::{
    Cache, ForwardCtx, GradStore, Module, ParamMut, ParamRef, Workspace, WsMat,
};
use super::plan::Sketchable;
use crate::linalg::{gemm, gemm_batch, matmul, Mat, MatMut, MatRef};
use crate::rng::{Philox, Rng};
use crate::util::memtrack::{MemError, MemGuard};

/// Default key-tile width of the dense attention backward (see
/// [`MultiHeadAttention::with_backward_tile`]): matches the GEMM's KC
/// blocking so a probability tile's K panel stays L2-resident.
pub const ATTN_BWD_TILE: usize = 64;

/// Zero the rows a sequence batch leaves uncovered (padding rows), so pad
/// positions of an attention output are exactly zero. Segments arrive
/// sorted and disjoint ([`super::module::SeqBatch::segments`]); with full
/// coverage this touches nothing.
fn zero_pad_rows(out: &mut Mat, segs: &[(usize, usize)]) {
    let n = out.rows();
    let mut next = 0usize;
    for &(off, len) in segs {
        for r in next..off {
            out.row_mut(r).fill(0.0);
        }
        next = off + len;
    }
    for r in next..n {
        out.row_mut(r).fill(0.0);
    }
}

/// Shared backward tail of both attention variants: given per-head input
/// gradients already assembled into `dq`/`dk`/`dv` (n×d, in *raw
/// projection* space) and the cached input, accumulate the projection
/// gradients and return `∂loss/∂x`.
///
/// `q = x·Wq` etc. ⇒ `dWq = xᵀ·dq`, `dx = dq·Wqᵀ + dk·Wkᵀ + dv·Wvᵀ`
/// (the output-projection term is added by the caller). The three weight
/// gradients run as one 3-item batched dispatch into d×d workspace
/// blocks; `dx` accumulates in place sequentially (a shared accumulate
/// target cannot batch) — no per-term temporaries either way.
fn attn_proj_backward(
    w: &AttnWeights,
    grads: &mut GradStore,
    ws: &Workspace,
    x: &Mat,
    dq: &Mat,
    dk: &Mat,
    dv: &Mat,
) -> Mat {
    let d = w.embed_dim;
    let n = x.rows();
    let mut dwq = ws.take(d, d);
    let mut dwk = ws.take(d, d);
    let mut dwv = ws.take(d, d);
    {
        let a = [x.view().t(), x.view().t(), x.view().t()];
        let b = [dq.view(), dk.view(), dv.view()];
        let mut c = [dwq.view_mut(), dwk.view_mut(), dwv.view_mut()];
        gemm_batch(1.0, &a, &b, 0.0, &mut c);
    }
    grads.accum("wq", 1.0, dwq.data());
    grads.accum("wk", 1.0, dwk.data());
    grads.accum("wv", 1.0, dwv.data());
    let mut dx = Mat::zeros(n, d);
    for (dproj, wmat) in [(dq, &w.wq), (dk, &w.wk), (dv, &w.wv)] {
        let a = [dproj.view()];
        let b = [wmat.view().t()];
        let mut c = [dx.view_mut()];
        gemm_batch(1.0, &a, &b, 1.0, &mut c);
    }
    dx
}

/// Named views of the shared Q/K/V/output projections (both attention
/// variants expose identical parameter state — the Performer's random
/// features are fixed, not trained, so they are deliberately absent).
fn attn_params(w: &AttnWeights) -> Vec<(String, ParamRef<'_>)> {
    vec![
        ("wq".to_string(), ParamRef::Mat(&w.wq)),
        ("wk".to_string(), ParamRef::Mat(&w.wk)),
        ("wv".to_string(), ParamRef::Mat(&w.wv)),
        ("wo".to_string(), ParamRef::Mat(&w.wo)),
    ]
}

fn attn_params_mut(w: &mut AttnWeights) -> Vec<(String, ParamMut<'_>)> {
    vec![
        ("wq".to_string(), ParamMut::Mat(&mut w.wq)),
        ("wk".to_string(), ParamMut::Mat(&mut w.wk)),
        ("wv".to_string(), ParamMut::Mat(&mut w.wv)),
        ("wo".to_string(), ParamMut::Mat(&mut w.wo)),
    ]
}

/// Random-feature kernel for the Performer (the paper benchmarks both).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// FAVOR+ positive features for the softmax kernel.
    Softmax,
    /// ReLU features.
    Relu,
}

/// Shared per-head projection weights (Q, K, V, output), so the dense and
/// random variants compare with identical parameter state.
#[derive(Clone, Debug)]
pub struct AttnWeights {
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub embed_dim: usize,
    pub num_heads: usize,
}

impl AttnWeights {
    pub fn random<R: Rng>(embed_dim: usize, num_heads: usize, rng: &mut R) -> Self {
        assert_eq!(embed_dim % num_heads, 0, "embed_dim must divide num_heads");
        let s = (1.0 / embed_dim as f32).sqrt();
        AttnWeights {
            wq: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            wk: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            wv: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            wo: Mat::randn(embed_dim, embed_dim, rng).scale(s),
            embed_dim,
            num_heads,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.embed_dim / self.num_heads
    }
}

/// Exact softmax multi-head attention (the `nn.MultiheadAttention`
/// baseline). Forward runs through the unified [`Module`] API.
#[derive(Clone)]
pub struct MultiHeadAttention {
    pub weights: AttnWeights,
    /// Head-group chunk size for the inference forward (0 = all heads at
    /// once) — see [`Module::set_head_group`].
    head_group: usize,
    /// Key-tile width of the recomputing backward (0 = [`ATTN_BWD_TILE`])
    /// — see [`MultiHeadAttention::with_backward_tile`].
    bwd_tile: usize,
    grads: GradStore,
}

/// Activation cache of [`MultiHeadAttention::forward_train`]: input, raw
/// projections, per-head softmax *row statistics*, and the pre-`Wo` head
/// concat. The `h·n×n` probability tensor is deliberately absent — the
/// tiled backward reconstructs each probability tile from Q/K and the
/// stats, so the cache is O(h·n), not O(h·n²).
struct MhaCache {
    x: Mat,
    q: Mat,
    k: Mat,
    v: Mat,
    /// Per-head, per-row softmax statistics `(max, exp_sum)`:
    /// `stats[head][row]`. Rows are absolute (pad rows hold zeros and are
    /// never read).
    stats: Vec<Vec<(f32, f32)>>,
    /// Head outputs concatenated (n×d), before the output projection.
    concat: Mat,
    /// The sequence segments the forward ran under (single full-length
    /// segment when no [`super::module::SeqBatch`] was installed).
    segs: Vec<(usize, usize)>,
    /// The forward's allocation guards — moved here instead of released,
    /// so the cached activations stay charged against the tracker for
    /// the cache's lifetime.
    _guards: Vec<MemGuard>,
}

impl MultiHeadAttention {
    pub fn new(weights: AttnWeights) -> Self {
        MultiHeadAttention {
            weights,
            head_group: 0,
            bwd_tile: 0,
            grads: GradStore::default(),
        }
    }

    /// Builder form of [`Module::set_head_group`].
    pub fn with_head_group(mut self, heads: usize) -> Self {
        self.head_group = heads;
        self
    }

    /// Set the key-tile width `T` of the recomputing backward (0 restores
    /// [`ATTN_BWD_TILE`]). Peak backward activation scales with `T`
    /// (O(h·n·T) probability/score tiles), not with n² — smaller tiles
    /// trade GEMM batching breadth for a lower training peak. Tiling
    /// never changes which gradient is computed, only how many key
    /// columns are in flight at once.
    pub fn with_backward_tile(mut self, tile: usize) -> Self {
        self.bwd_tile = tile;
        self
    }

    /// Effective backward key-tile width.
    fn backward_tile(&self) -> usize {
        if self.bwd_tile == 0 {
            ATTN_BWD_TILE
        } else {
            self.bwd_tile
        }
    }

    /// Effective chunk size (shared definition: 0 → all heads, else
    /// clamped to `[1, num_heads]`).
    fn head_group_size(&self) -> usize {
        super::module::effective_head_group(self.head_group, self.weights.num_heads)
    }

    /// Self-attention forward on `x: n × d`, tracking every temporary in
    /// `ctx.mem()`. Returns `n × d` or a budget error (the Fig. 3 "x").
    /// With `want_cache`, also returns the activations backward needs —
    /// otherwise every scratch block returns to the context's workspace.
    fn forward_with(
        &self,
        x: &Mat,
        ctx: &ForwardCtx,
        want_cache: bool,
    ) -> Result<(Mat, Option<MhaCache>), MemError> {
        let mem = ctx.mem();
        let ws = ctx.workspace();
        let w = &self.weights;
        let n = x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        assert_eq!(x.cols(), d);
        // Projections (each n×d). On the inference path the guards release
        // on return; a training forward moves them into the cache so the
        // retained activations stay accounted until backward.
        let segs = ctx.segments_for(n);
        let max_len = segs.iter().map(|&(_, l)| l).max().unwrap_or(0);
        let gq = mem.alloc((n * d * 4) as u64)?;
        let mut q = ws.take(n, d);
        gemm(1.0, x, &w.wq, 0.0, &mut q);
        let gk = mem.alloc((n * d * 4) as u64)?;
        let mut k = ws.take(n, d);
        gemm(1.0, x, &w.wk, 0.0, &mut k);
        let gv = mem.alloc((n * d * 4) as u64)?;
        let mut v = ws.take(n, d);
        gemm(1.0, x, &w.wv, 0.0, &mut v);
        let go = mem.alloc((n * d * 4) as u64)?;
        let mut out = ws.take(n, d);
        let scale = 1.0 / (dh as f32).sqrt();
        // The dense score tensor is what blows memory on GPUs; PyTorch
        // materializes (h, n, n) at once. By default we account (and
        // compute) the same — batched products over strided per-head
        // views, 1/√dh folded into alpha — but the head-group knob bounds
        // the live scores to `group` heads at a time on the inference
        // path, trading some batching breadth for an (h/group)× smaller
        // peak. Chunking never changes results: each head's products and
        // softmax are computed independently either way. Training
        // forwards run one head at a time: since the cache retains only
        // O(n) row statistics (not the probabilities), chunking now
        // *does* bound the training-forward peak to one n×n block.
        //
        // With a sequence batch, every cross-row product below runs per
        // segment over exact-length row views — scores are len×len, so a
        // row's softmax never sees another sequence's keys and pad
        // positions carry exactly zero weight. One full-length segment
        // makes every view a no-op re-description of the full matrices:
        // the identical batched products execute, bitwise.
        let group = if want_cache { 1 } else { self.head_group_size() };
        let gscores = mem.alloc((group * max_len * max_len * 4) as u64)?;
        let mut stats: Vec<Vec<(f32, f32)>> = if want_cache {
            vec![vec![(0f32, 0f32); n]; h]
        } else {
            Vec::new()
        };
        let gstats = if want_cache {
            Some(mem.alloc((h * n * 8) as u64)?)
        } else {
            None
        };
        zero_pad_rows(&mut out, &segs);
        for &(off, len) in &segs {
            let mut h0 = 0;
            while h0 < h {
                let h1 = (h0 + group).min(h);
                let mut scores: Vec<WsMat> = (h0..h1).map(|_| ws.take(len, len)).collect();
                {
                    let a: Vec<MatRef> = (h0..h1)
                        .map(|i| {
                            q.view()
                                .row_range(off, off + len)
                                .col_range(i * dh, (i + 1) * dh)
                        })
                        .collect();
                    let b: Vec<MatRef> = (h0..h1)
                        .map(|i| {
                            k.view()
                                .row_range(off, off + len)
                                .col_range(i * dh, (i + 1) * dh)
                                .t()
                        })
                        .collect();
                    let mut c: Vec<MatMut> = scores.iter_mut().map(|s| s.view_mut()).collect();
                    gemm_batch(scale, &a, &b, 0.0, &mut c);
                }
                // Row softmax per head, recording (max, exp-sum) per row
                // for the recomputing backward.
                for (idx, s) in scores.iter_mut().enumerate() {
                    for i in 0..len {
                        let row = s.row_mut(i);
                        let mut mx = f32::NEG_INFINITY;
                        for v in row.iter() {
                            mx = mx.max(*v);
                        }
                        let mut sum = 0f32;
                        for v in row.iter_mut() {
                            *v = (*v - mx).exp();
                            sum += *v;
                        }
                        for v in row.iter_mut() {
                            *v /= sum;
                        }
                        if want_cache {
                            stats[h0 + idx][off + i] = (mx, sum);
                        }
                    }
                }
                // Head outputs P_h·V_h straight into disjoint column
                // bands of the concat matrix (narrowed to this segment's
                // rows) — batched, no per-head copy-out.
                {
                    let a: Vec<MatRef> = scores.iter().map(|s| s.view()).collect();
                    let b: Vec<MatRef> = (h0..h1)
                        .map(|i| {
                            v.view()
                                .row_range(off, off + len)
                                .col_range(i * dh, (i + 1) * dh)
                        })
                        .collect();
                    let mut c: Vec<MatMut> = out
                        .col_bands_mut(dh)
                        .into_iter()
                        .skip(h0)
                        .take(h1 - h0)
                        .map(|band| band.row_range(off, off + len))
                        .collect();
                    gemm_batch(1.0, &a, &b, 0.0, &mut c);
                }
                h0 = h1;
            }
        }
        drop(gscores);
        let y = matmul(&out, &w.wo);
        let cache = if want_cache {
            let mut guards = vec![gq, gk, gv, go];
            guards.extend(gstats);
            Some(MhaCache {
                x: x.clone(),
                q: q.detach(),
                k: k.detach(),
                v: v.detach(),
                stats,
                concat: out.detach(),
                segs,
                _guards: guards,
            })
        } else {
            None
        };
        Ok((y, cache))
    }
}

impl Module for MultiHeadAttention {
    fn type_name(&self) -> &'static str {
        "MultiheadAttention"
    }

    fn io_dims(&self) -> Option<(usize, usize)> {
        Some((self.weights.embed_dim, self.weights.embed_dim))
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        Ok(self.forward_with(x, ctx, false)?.0)
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let (y, cache) = self.forward_with(x, ctx, true)?;
        Ok((y, Cache::new(cache.expect("cache requested"))))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &MhaCache = cache.downcast::<MhaCache>()?;
        let w = &self.weights;
        let n = c.x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        anyhow::ensure!(
            g.shape() == (n, d),
            "grad_out shape {:?} vs expected ({n}, {d})",
            g.shape()
        );
        let max_len = c.segs.iter().map(|&(_, l)| l).max().unwrap_or(0);
        let tile = self.backward_tile().min(max_len.max(1));
        // Dominant transients: dq/dk/dv/dconcat (n×d each) plus the tiled
        // probability/score-gradient blocks — h len×T pairs for the tile
        // in flight and the T×d dK/dV staging blocks. The h·n×n term of
        // the materializing backward is gone; the peak scales with the
        // tile width, not n².
        let _act = ctx
            .mem()
            .alloc(((4 * n * d + 2 * h * max_len * tile + 2 * tile * d) * 4) as u64)?;
        let ws = ctx.workspace();
        let scale = 1.0 / (dh as f32).sqrt();
        // Output projection: y = concat·Wo ⇒ dWo = concatᵀ·g, dconcat = g·Woᵀ.
        {
            let mut dwo = ws.take(d, d);
            let a = [c.concat.view().t()];
            let b = [g.view()];
            let mut cb = [dwo.view_mut()];
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
            self.grads.accum("wo", 1.0, dwo.data());
        }
        let mut dconcat = ws.take(n, d);
        {
            let a = [g.view()];
            let b = [w.wo.view().t()];
            let mut cb = [dconcat.view_mut()];
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
        }
        // dq accumulates across key tiles (beta = 1); dk/dv rows are
        // written exactly once per tile. Zeroed so pad rows contribute
        // nothing downstream.
        let mut dq = ws.take_zeroed(n, d);
        let mut dk = ws.take_zeroed(n, d);
        let mut dv = ws.take_zeroed(n, d);
        for &(off, len) in &c.segs {
            // Softmax row-dot per head via the output identity:
            //   D_i = Σ_j dP_ij·P_ij = Σ_c doh_ic·oh_ic   (oh = P·Vh),
            // computed from the cached concat in f64 — one O(n·d) pass
            // replaces the per-tile accumulation a two-pass scheme needs.
            let dvals: Vec<Vec<f32>> = (0..h)
                .map(|head| {
                    let c0 = head * dh;
                    (0..len)
                        .map(|i| {
                            let r = off + i;
                            dconcat.row(r)[c0..c0 + dh]
                                .iter()
                                .zip(&c.concat.row(r)[c0..c0 + dh])
                                .map(|(&a, &b)| a as f64 * b as f64)
                                .sum::<f64>() as f32
                        })
                        .collect()
                })
                .collect();
            let mut t0 = 0;
            while t0 < len {
                let t1 = (t0 + tile).min(len);
                let tw = t1 - t0;
                // Recompute the probability tile: S = scale·Q_h·K_h[t]ᵀ,
                // then P = exp(S − m_i)/s_i from the cached row stats.
                let mut pt: Vec<WsMat> = (0..h).map(|_| ws.take(len, tw)).collect();
                {
                    let a: Vec<MatRef> = (0..h)
                        .map(|i| {
                            c.q.view()
                                .row_range(off, off + len)
                                .col_range(i * dh, (i + 1) * dh)
                        })
                        .collect();
                    let b: Vec<MatRef> = (0..h)
                        .map(|i| {
                            c.k.view()
                                .row_range(off + t0, off + t1)
                                .col_range(i * dh, (i + 1) * dh)
                                .t()
                        })
                        .collect();
                    let mut cb: Vec<MatMut> = pt.iter_mut().map(|s| s.view_mut()).collect();
                    gemm_batch(scale, &a, &b, 0.0, &mut cb);
                }
                for (head, p) in pt.iter_mut().enumerate() {
                    for i in 0..len {
                        let (mx, sum) = c.stats[head][off + i];
                        for v in p.row_mut(i) {
                            *v = (*v - mx).exp() / sum;
                        }
                    }
                }
                // dVh[t] = P_tᵀ·doh — batched into a T×d staging block's
                // head bands, then row-copied into dv (a MatMut column
                // band can narrow rows, but dv's tile rows live in every
                // band, so a single contiguous copy per row is simpler
                // and touches each element once).
                {
                    let mut dvt = ws.take(tw, d);
                    {
                        let a: Vec<MatRef> = pt.iter().map(|s| s.view().t()).collect();
                        let b: Vec<MatRef> = (0..h)
                            .map(|i| {
                                dconcat
                                    .view()
                                    .row_range(off, off + len)
                                    .col_range(i * dh, (i + 1) * dh)
                            })
                            .collect();
                        let mut cb = dvt.col_bands_mut(dh);
                        gemm_batch(1.0, &a, &b, 0.0, &mut cb);
                    }
                    for r in 0..tw {
                        dv.row_mut(off + t0 + r).copy_from_slice(dvt.row(r));
                    }
                }
                // dP tile = doh·Vh[t]ᵀ (reused in place for dS below).
                let mut dst: Vec<WsMat> = (0..h).map(|_| ws.take(len, tw)).collect();
                {
                    let a: Vec<MatRef> = (0..h)
                        .map(|i| {
                            dconcat
                                .view()
                                .row_range(off, off + len)
                                .col_range(i * dh, (i + 1) * dh)
                        })
                        .collect();
                    let b: Vec<MatRef> = (0..h)
                        .map(|i| {
                            c.v.view()
                                .row_range(off + t0, off + t1)
                                .col_range(i * dh, (i + 1) * dh)
                                .t()
                        })
                        .collect();
                    let mut cb: Vec<MatMut> = dst.iter_mut().map(|s| s.view_mut()).collect();
                    gemm_batch(1.0, &a, &b, 0.0, &mut cb);
                }
                // Row-softmax backward on the tile:
                // dS_ij = P_ij·(dP_ij − D_i).
                for head in 0..h {
                    let p = &pt[head];
                    let dsh = &mut dst[head];
                    for i in 0..len {
                        let di = dvals[head][i];
                        for (sv, &pv) in dsh.row_mut(i).iter_mut().zip(p.row(i)) {
                            *sv = pv * (*sv - di);
                        }
                    }
                }
                // S = scale·Qh·Khᵀ ⇒ dQh += scale·dS·Kh[t] (accumulated
                // across tiles), dKh[t] = scale·dSᵀ·Qh (staged + copied).
                {
                    let a: Vec<MatRef> = dst.iter().map(|s| s.view()).collect();
                    let b: Vec<MatRef> = (0..h)
                        .map(|i| {
                            c.k.view()
                                .row_range(off + t0, off + t1)
                                .col_range(i * dh, (i + 1) * dh)
                        })
                        .collect();
                    let mut cb: Vec<MatMut> = dq
                        .col_bands_mut(dh)
                        .into_iter()
                        .map(|band| band.row_range(off, off + len))
                        .collect();
                    gemm_batch(scale, &a, &b, 1.0, &mut cb);
                }
                {
                    let mut dkt = ws.take(tw, d);
                    {
                        let a: Vec<MatRef> = dst.iter().map(|s| s.view().t()).collect();
                        let b: Vec<MatRef> = (0..h)
                            .map(|i| {
                                c.q.view()
                                    .row_range(off, off + len)
                                    .col_range(i * dh, (i + 1) * dh)
                            })
                            .collect();
                        let mut cb = dkt.col_bands_mut(dh);
                        gemm_batch(scale, &a, &b, 0.0, &mut cb);
                    }
                    for r in 0..tw {
                        dk.row_mut(off + t0 + r).copy_from_slice(dkt.row(r));
                    }
                }
                t0 = t1;
            }
        }
        let dx = attn_proj_backward(&self.weights, &mut self.grads, ws, &c.x, &dq, &dk, &dv);
        Ok(dx)
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn scale_grads(&mut self, s: f32) {
        self.grads.scale(s);
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        attn_params(&self.weights)
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        attn_params_mut(&mut self.weights)
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn set_head_group(&mut self, heads: usize) {
        self.head_group = heads;
    }

    fn is_sequence_aware(&self) -> bool {
        true
    }

    fn as_sketchable(&self) -> Option<&dyn Sketchable> {
        Some(self)
    }
}

/// Performer-style random-feature attention — Panther's
/// `RandMultiHeadAttention`. Forward runs through the unified [`Module`]
/// API.
#[derive(Clone)]
pub struct RandMultiHeadAttention {
    pub weights: AttnWeights,
    pub num_features: usize,
    pub kernel: KernelKind,
    /// Per-head random projection `ω: d_h × m` (orthogonal-ish gaussian).
    features: Vec<Mat>,
    /// Head-group chunk size for the inference forward (0 = all heads at
    /// once) — see [`Module::set_head_group`].
    head_group: usize,
    grads: GradStore,
}

/// Per-head slice of [`RandMhaCache`]: everything the linear-attention
/// backward reuses — all `O(n·m + m·d_h)`, never `n×n`. The feature-map
/// *inputs* live in the cache-level `qs`/`ks`/`v` matrices (head slices
/// are column views, not copies).
struct PerfHead {
    phi_q: Mat,
    phi_k: Mat,
    /// `φ(K)ᵀ·V` (m × d_h).
    kv: Mat,
    /// Normalizer `φ(K)ᵀ·1` (length m).
    z: Vec<f32>,
    /// Numerator `φ(Q)·kv` (n × d_h).
    num: Mat,
    /// Pre-clamp denominators `φ(Q)_i·z` — backward zeroes the normalizer
    /// gradient where the forward's `max(·, 1e-9)` clamp was active.
    den_raw: Vec<f32>,
}

/// Activation cache of [`RandMultiHeadAttention::forward_train`].
struct RandMhaCache {
    x: Mat,
    /// Q/K projections pre-scaled by 1/√dh (the feature-map inputs) and
    /// the raw V projection; per-head slices are column views into these.
    qs: Mat,
    ks: Mat,
    v: Mat,
    /// Head outputs concatenated (n×d), before the output projection.
    concat: Mat,
    /// Per-(segment, head) state, segment-major: entry `si*h + head`
    /// (matrix rows are segment-local). One segment with no `SeqBatch`.
    heads: Vec<PerfHead>,
    /// The sequence segments the forward ran under.
    segs: Vec<(usize, usize)>,
    /// The forward's allocation guards (projections + per-head state) —
    /// kept charged for the cache's lifetime.
    _guards: Vec<MemGuard>,
}

/// Overwrite a random-feature projection block `proj = x_h·ω_h` with the
/// FAVOR+ feature map φ — the ONE copy of the formula, shared by the
/// batched forward and the streaming decode path. Softmax kernel:
/// `φ = exp(proj − ‖x‖²/2 − c)/√m` (positive, with a *scalar* stabilizer
/// `c`, shared by all rows — a per-row stabilizer would reweight keys and
/// bias the attention estimate); ReLU kernel: `φ = max(proj, 0)/√m`.
/// `xs` holds the scaled inputs; the head's slice is columns
/// `[c0, c0+dh)` and `proj` row `i` corresponds to `xs` row `row0 + i`
/// (segment-local feature blocks pass their sequence's row offset).
/// `stab`: `None` = the block's max (batch path); streaming passes
/// `Some(0.0)` — the stabilizer must be constant across time steps or
/// the accumulated KV state mixes inconsistently-scaled features.
fn phi_in_place(
    kernel: KernelKind,
    proj: &mut Mat,
    xs: &Mat,
    row0: usize,
    c0: usize,
    dh: usize,
    stab: Option<f32>,
) {
    let s = 1.0 / (proj.cols() as f32).sqrt();
    match kernel {
        KernelKind::Softmax => {
            let c = stab.unwrap_or_else(|| {
                proj.data()
                    .iter()
                    .cloned()
                    .fold(f32::NEG_INFINITY, f32::max)
            });
            for i in 0..proj.rows() {
                let sq: f32 =
                    xs.row(row0 + i)[c0..c0 + dh].iter().map(|&v| v * v).sum::<f32>() / 2.0;
                for o in proj.row_mut(i) {
                    *o = (*o - sq - c).exp() * s;
                }
            }
        }
        KernelKind::Relu => {
            for v in proj.data_mut() {
                *v = v.max(0.0) * s;
            }
        }
    }
}

/// Shared tail of the FAVOR+ backward for one projection side (q or k):
/// convert `dφ` to `e` in place (softmax features `φ = exp(ωᵀx − ‖x‖²/2
/// − c)/√m` give `e = dφ⊙φ`; ReLU features give `e = s·dφ` where
/// `φ > 0`), run the batched `e·ωᵀ` products into `dst`'s head bands with
/// the 1/√dh return-to-raw-projection-space factor folded into alpha, and
/// apply the softmax kernel's `−rowsum(e)·x` term. The stabilizer `c` is
/// treated as a constant: the normalized attention output is exactly
/// invariant to it (it rescales numerator and denominator identically),
/// so its true gradient contribution is zero. `dphi`/`phis` rows are
/// segment-local; `off` is the segment's first row in `xs`/`dst` (0 when
/// the whole batch is one sequence).
#[allow(clippy::too_many_arguments)]
fn favor_feature_backward(
    kernel: KernelKind,
    features: &[Mat],
    dphi: &mut [WsMat],
    phis: &[&Mat],
    xs: &Mat,
    scale: f32,
    dh: usize,
    off: usize,
    dst: &mut Mat,
) {
    let len = dphi.first().map_or(0, |e| e.rows());
    match kernel {
        KernelKind::Softmax => {
            for (e, phi) in dphi.iter_mut().zip(phis) {
                for (ev, &pv) in e.data_mut().iter_mut().zip(phi.data()) {
                    *ev *= pv;
                }
            }
        }
        KernelKind::Relu => {
            let s = 1.0 / (features[0].cols() as f32).sqrt();
            for (e, phi) in dphi.iter_mut().zip(phis) {
                for (ev, &pv) in e.data_mut().iter_mut().zip(phi.data()) {
                    *ev = if pv > 0.0 { *ev * s } else { 0.0 };
                }
            }
        }
    }
    {
        let a: Vec<MatRef> = dphi.iter().map(|e| e.view()).collect();
        let b: Vec<MatRef> = features.iter().map(|f| f.view().t()).collect();
        let mut c: Vec<MatMut> = dst
            .col_bands_mut(dh)
            .into_iter()
            .map(|band| band.row_range(off, off + len))
            .collect();
        gemm_batch(scale, &a, &b, 0.0, &mut c);
    }
    if matches!(kernel, KernelKind::Softmax) {
        for (head, e) in dphi.iter().enumerate() {
            let c0 = head * dh;
            for i in 0..len {
                let rs: f32 = e.row(i).iter().sum();
                let xrow = &xs.row(off + i)[c0..c0 + dh];
                let drow = &mut dst.row_mut(off + i)[c0..c0 + dh];
                for (dv, &xv) in drow.iter_mut().zip(xrow) {
                    *dv -= scale * rs * xv;
                }
            }
        }
    }
}

impl RandMultiHeadAttention {
    pub fn new(weights: AttnWeights, num_features: usize, kernel: KernelKind, seed: u64) -> Self {
        let dh = weights.head_dim();
        let mut rng = Philox::seeded(seed);
        let features = (0..weights.num_heads)
            .map(|_| Mat::randn(dh, num_features, &mut rng))
            .collect();
        RandMultiHeadAttention {
            weights,
            num_features,
            kernel,
            features,
            head_group: 0,
            grads: GradStore::default(),
        }
    }

    /// Builder form of [`Module::set_head_group`].
    pub fn with_head_group(mut self, heads: usize) -> Self {
        self.head_group = heads;
        self
    }

    /// Effective chunk size (shared definition: 0 → all heads, else
    /// clamped to `[1, num_heads]`).
    fn head_group_size(&self) -> usize {
        super::module::effective_head_group(self.head_group, self.weights.num_heads)
    }

    /// Feature map over a standalone head input (the streaming decode
    /// path and tests; the batch forward applies [`phi_in_place`] to
    /// whole projection blocks — same single formula either way).
    fn feature_map_with_stab(&self, xh: &Mat, head: usize, stab: Option<f32>) -> Mat {
        let mut phi = matmul(xh, &self.features[head]); // n × m
        phi_in_place(self.kernel, &mut phi, xh, 0, 0, xh.cols(), stab);
        phi
    }

    /// Extra parameters vs dense attention: the random features are fixed
    /// (not trained), so the parameter count is identical to dense MHA.
    pub fn feature_state_bytes(&self) -> u64 {
        (self.weights.num_heads * self.weights.head_dim() * self.num_features * 4) as u64
    }

    /// Linear-attention forward: `out = φ(Q)·(φ(K)ᵀV) / (φ(Q)·φ(K)ᵀ1)`.
    /// Never materializes an n×n matrix — peak extra memory is
    /// `O(h·(n·m + m·d_h))` with every head's state alive at once for the
    /// batched products (still linear in n). With `want_cache`, the
    /// per-head blocks are detached into the cache for backward instead
    /// of returning to the workspace.
    fn forward_with(
        &self,
        x: &Mat,
        ctx: &ForwardCtx,
        want_cache: bool,
    ) -> Result<(Mat, Option<RandMhaCache>), MemError> {
        let mem = ctx.mem();
        let ws = ctx.workspace();
        let w = &self.weights;
        let n = x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        let m = self.num_features;
        assert_eq!(x.cols(), d);
        let segs = ctx.segments_for(n);
        let scale = 1.0 / (dh as f32).sqrt();
        let gq = mem.alloc((n * d * 4) as u64)?;
        let mut qs = ws.take(n, d);
        gemm(1.0, x, &w.wq, 0.0, &mut qs);
        let gk = mem.alloc((n * d * 4) as u64)?;
        let mut ks = ws.take(n, d);
        gemm(1.0, x, &w.wk, 0.0, &mut ks);
        let gv = mem.alloc((n * d * 4) as u64)?;
        let mut v = ws.take(n, d);
        gemm(1.0, x, &w.wv, 0.0, &mut v);
        // The feature maps read 1/√dh-scaled Q/K; scaling the whole block
        // once replaces the old per-head slice+scale copies.
        for val in qs.data_mut() {
            *val *= scale;
        }
        for val in ks.data_mut() {
            *val *= scale;
        }
        let go = mem.alloc((n * d * 4) as u64)?;
        let mut out = ws.take(n, d);
        zero_pad_rows(&mut out, &segs);
        // Per-head state for the batched products — φ(Q), φ(K) (len×m
        // each), KV state (m×dh), normalizer (m) — alive for `group`
        // heads at a time, one sequence segment at a time. The default
        // keeps all h heads live (maximum batching breadth); the
        // head-group knob bounds the documented ×h on the Performer's
        // O(n) footprint on the inference path without changing results
        // (per-head chains are independent). Training forwards always run
        // un-chunked: the cache retains every head's state anyway.
        // Inference returns every block to the workspace (and its
        // accounting) per segment; a training forward moves each
        // segment's guard into the cache so the retained state stays
        // accounted until backward. Restricting the φ(K)ᵀ·V and
        // normalizer sums to a segment's rows is exactly the FAVOR+
        // masking: a pad position contributes nothing to any denominator.
        let group = if want_cache { h } else { self.head_group_size() };
        let mut heads_cache: Vec<PerfHead> = Vec::new();
        let mut cache_guards: Vec<MemGuard> = vec![gq, gk, gv, go];
        for &(off, len) in &segs {
            let ghead =
                mem.alloc((group as u64) * ((2 * len * m + m * dh + m) * 4) as u64)?;
            let mut h0 = 0;
            while h0 < h {
                let h1 = (h0 + group).min(h);
                let cg = h1 - h0;
                // Feature projections x_h·ω_h for both sides — batched —
                // then the elementwise feature map in place.
                let mut phi_q: Vec<WsMat> = (0..cg).map(|_| ws.take(len, m)).collect();
                let mut phi_k: Vec<WsMat> = (0..cg).map(|_| ws.take(len, m)).collect();
                for (phis, xs) in [(&mut phi_q, &qs), (&mut phi_k, &ks)] {
                    {
                        let a: Vec<MatRef> = (h0..h1)
                            .map(|i| {
                                xs.view()
                                    .row_range(off, off + len)
                                    .col_range(i * dh, (i + 1) * dh)
                            })
                            .collect();
                        let b: Vec<MatRef> =
                            self.features[h0..h1].iter().map(|f| f.view()).collect();
                        let mut c: Vec<MatMut> = phis.iter_mut().map(|p| p.view_mut()).collect();
                        gemm_batch(1.0, &a, &b, 0.0, &mut c);
                    }
                    for (idx, p) in phis.iter_mut().enumerate() {
                        phi_in_place(self.kernel, p, xs, off, (h0 + idx) * dh, dh, None);
                    }
                }
                // KV state: φ(K)ᵀ·V (m × dh) — the O(1)-in-n state —
                // batched over the segment's rows only.
                let mut kv: Vec<WsMat> = (0..cg).map(|_| ws.take(m, dh)).collect();
                {
                    let a: Vec<MatRef> = phi_k.iter().map(|p| p.view().t()).collect();
                    let b: Vec<MatRef> = (h0..h1)
                        .map(|i| {
                            v.view()
                                .row_range(off, off + len)
                                .col_range(i * dh, (i + 1) * dh)
                        })
                        .collect();
                    let mut c: Vec<MatMut> = kv.iter_mut().map(|s| s.view_mut()).collect();
                    gemm_batch(1.0, &a, &b, 0.0, &mut c);
                }
                // Normalizers: z = φ(K)ᵀ·1 (length m) per head — valid
                // positions only, so pad keys never inflate a denominator.
                let z: Vec<Vec<f32>> = phi_k
                    .iter()
                    .map(|pk| {
                        let mut zv = vec![0f32; m];
                        for i in 0..len {
                            for (zj, &pj) in zv.iter_mut().zip(pk.row(i)) {
                                *zj += pj;
                            }
                        }
                        zv
                    })
                    .collect();
                // Numerators: φ(Q)·kv (len × dh) — batched.
                let mut num: Vec<WsMat> = (0..cg).map(|_| ws.take(len, dh)).collect();
                {
                    let a: Vec<MatRef> = phi_q.iter().map(|p| p.view()).collect();
                    let b: Vec<MatRef> = kv.iter().map(|s| s.view()).collect();
                    let mut c: Vec<MatMut> = num.iter_mut().map(|s| s.view_mut()).collect();
                    gemm_batch(1.0, &a, &b, 0.0, &mut c);
                }
                // out rows: num / max(φ(Q)·z, 1e-9) per head.
                let mut den_raw: Vec<Vec<f32>> = Vec::with_capacity(cg);
                for idx in 0..cg {
                    let c0 = (h0 + idx) * dh;
                    let pq = &phi_q[idx];
                    let mut dr = vec![0f32; len];
                    for i in 0..len {
                        let dot: f32 = pq
                            .row(i)
                            .iter()
                            .zip(&z[idx])
                            .map(|(&a, &b)| a * b)
                            .sum::<f32>();
                        dr[i] = dot;
                        let denom = dot.max(1e-9);
                        let orow = &mut out.row_mut(off + i)[c0..c0 + dh];
                        for (o, &nv) in orow.iter_mut().zip(num[idx].row(i)) {
                            *o = nv / denom;
                        }
                    }
                    den_raw.push(dr);
                }
                if want_cache {
                    let iter = phi_q
                        .into_iter()
                        .zip(phi_k)
                        .zip(kv)
                        .zip(num)
                        .zip(z)
                        .zip(den_raw);
                    for (((((pq, pk), kvh), numh), zh), drh) in iter {
                        heads_cache.push(PerfHead {
                            phi_q: pq.detach(),
                            phi_k: pk.detach(),
                            kv: kvh.detach(),
                            z: zh,
                            num: numh.detach(),
                            den_raw: drh,
                        });
                    }
                }
                h0 = h1;
            }
            if want_cache {
                cache_guards.push(ghead);
            }
        }
        let y = matmul(&out, &w.wo);
        let cache = if want_cache {
            let heads = heads_cache;
            Some(RandMhaCache {
                x: x.clone(),
                qs: qs.detach(),
                ks: ks.detach(),
                v: v.detach(),
                concat: out.detach(),
                heads,
                segs,
                _guards: cache_guards,
            })
        } else {
            None
        };
        Ok((y, cache))
    }

    /// Start an autoregressive decode session. Performer's linear attention
    /// admits O(1)-per-token causal decoding: the per-head running state is
    /// just `φ(K)ᵀV (m × d_h)` plus the normalizer `φ(K)ᵀ1 (m)` — constant
    /// in sequence length, unlike a softmax KV cache which grows O(n).
    pub fn start_stream(&self) -> PerformerStream<'_> {
        let h = self.weights.num_heads;
        let dh = self.weights.head_dim();
        let m = self.num_features;
        PerformerStream {
            attn: self,
            kv: vec![Mat::zeros(m, dh); h],
            z: vec![vec![0f32; m]; h],
            tokens_seen: 0,
        }
    }
}

impl Module for RandMultiHeadAttention {
    fn type_name(&self) -> &'static str {
        "RandMultiheadAttention"
    }

    fn io_dims(&self) -> Option<(usize, usize)> {
        Some((self.weights.embed_dim, self.weights.embed_dim))
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        Ok(self.forward_with(x, ctx, false)?.0)
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let (y, cache) = self.forward_with(x, ctx, true)?;
        Ok((y, Cache::new(cache.expect("cache requested"))))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &RandMhaCache = cache.downcast::<RandMhaCache>()?;
        let w = &self.weights;
        let n = c.x.rows();
        let d = w.embed_dim;
        let h = w.num_heads;
        let dh = w.head_dim();
        let m = self.num_features;
        anyhow::ensure!(
            g.shape() == (n, d),
            "grad_out shape {:?} vs expected ({n}, {d})",
            g.shape()
        );
        anyhow::ensure!(
            c.heads.len() == c.segs.len() * h,
            "cache head count mismatch"
        );
        let max_len = c.segs.iter().map(|&(_, l)| l).max().unwrap_or(0);
        // Dominant transients: dq/dk/dv/dconcat (n×d each) plus one
        // segment's dφ blocks (2·len×m per head, alive at once for the
        // batched chain) — still linear in n, like the forward.
        let _act = ctx
            .mem()
            .alloc(((4 * n * d + h * 2 * max_len * m) * 4) as u64)?;
        let ws = ctx.workspace();
        let scale = 1.0 / (dh as f32).sqrt();
        // Output projection: y = concat·Wo ⇒ dWo = concatᵀ·g, dconcat = g·Woᵀ.
        {
            let mut dwo = ws.take(d, d);
            let a = [c.concat.view().t()];
            let b = [g.view()];
            let mut cb = [dwo.view_mut()];
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
            self.grads.accum("wo", 1.0, dwo.data());
        }
        let mut dconcat = ws.take(n, d);
        {
            let a = [g.view()];
            let b = [w.wo.view().t()];
            let mut cb = [dconcat.view_mut()];
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
        }
        // Zeroed so pad rows (never written by any segment) stay zero.
        let mut dq = ws.take_zeroed(n, d);
        let mut dk = ws.take_zeroed(n, d);
        let mut dv = ws.take_zeroed(n, d);
        for (si, &(off, len)) in c.segs.iter().enumerate() {
            let heads = &c.heads[si * h..(si + 1) * h];
            // out_i = num_i / den_i with den = max(φq_i·z, 1e-9):
            //   d_num_i = doh_i/den_i,
            //   d_den_i = −(doh_i·num_i)/den_i²  (zero where the clamp hit).
            let mut d_num: Vec<WsMat> = (0..h).map(|_| ws.take(len, dh)).collect();
            let mut d_den: Vec<Vec<f32>> = vec![vec![0f32; len]; h];
            for head in 0..h {
                let hc = &heads[head];
                let c0 = head * dh;
                let dn = &mut d_num[head];
                let dd = &mut d_den[head];
                for i in 0..len {
                    let doh_row = &dconcat.row(off + i)[c0..c0 + dh];
                    let den = hc.den_raw[i].max(1e-9);
                    for (dnv, &gv) in dn.row_mut(i).iter_mut().zip(doh_row) {
                        *dnv = gv / den;
                    }
                    if hc.den_raw[i] > 1e-9 {
                        let gn: f64 = doh_row
                            .iter()
                            .zip(hc.num.row(i))
                            .map(|(&a, &b)| a as f64 * b as f64)
                            .sum();
                        dd[i] = -(gn / (den as f64 * den as f64)) as f32;
                    }
                }
            }
            // num = φq·kv, den = φq·z:
            //   dφq = d_num·kvᵀ + d_den⊗z,  d_kv = φqᵀ·d_num,  dz = φqᵀ·d_den.
            let mut dphi_q: Vec<WsMat> = (0..h).map(|_| ws.take(len, m)).collect();
            {
                let a: Vec<MatRef> = d_num.iter().map(|s| s.view()).collect();
                let b: Vec<MatRef> = heads.iter().map(|hc| hc.kv.view().t()).collect();
                let mut cb: Vec<MatMut> = dphi_q.iter_mut().map(|s| s.view_mut()).collect();
                gemm_batch(1.0, &a, &b, 0.0, &mut cb);
            }
            for head in 0..h {
                let hc = &heads[head];
                for i in 0..len {
                    let ddv = d_den[head][i];
                    for (pv, &zv) in dphi_q[head].row_mut(i).iter_mut().zip(&hc.z) {
                        *pv += ddv * zv;
                    }
                }
            }
            let mut d_kv: Vec<WsMat> = (0..h).map(|_| ws.take(m, dh)).collect();
            {
                let a: Vec<MatRef> = heads.iter().map(|hc| hc.phi_q.view().t()).collect();
                let b: Vec<MatRef> = d_num.iter().map(|s| s.view()).collect();
                let mut cb: Vec<MatMut> = d_kv.iter_mut().map(|s| s.view_mut()).collect();
                gemm_batch(1.0, &a, &b, 0.0, &mut cb);
            }
            let dz: Vec<Vec<f32>> = (0..h)
                .map(|head| heads[head].phi_q.matvec_t(&d_den[head]))
                .collect();
            // kv = φkᵀ·vh, z = φkᵀ·1:
            //   dφk = vh·d_kvᵀ + 1⊗dz,  dvh = φk·d_kv.
            let mut dphi_k: Vec<WsMat> = (0..h).map(|_| ws.take(len, m)).collect();
            {
                let a: Vec<MatRef> = (0..h)
                    .map(|i| {
                        c.v.view()
                            .row_range(off, off + len)
                            .col_range(i * dh, (i + 1) * dh)
                    })
                    .collect();
                let b: Vec<MatRef> = d_kv.iter().map(|s| s.view().t()).collect();
                let mut cb: Vec<MatMut> = dphi_k.iter_mut().map(|s| s.view_mut()).collect();
                gemm_batch(1.0, &a, &b, 0.0, &mut cb);
            }
            for head in 0..h {
                for i in 0..len {
                    for (pv, &zv) in dphi_k[head].row_mut(i).iter_mut().zip(&dz[head]) {
                        *pv += zv;
                    }
                }
            }
            // dVh = φk·d_kv — batched straight into dv's column bands
            // (narrowed to this segment's rows).
            {
                let a: Vec<MatRef> = heads.iter().map(|hc| hc.phi_k.view()).collect();
                let b: Vec<MatRef> = d_kv.iter().map(|s| s.view()).collect();
                let mut cb: Vec<MatMut> = dv
                    .col_bands_mut(dh)
                    .into_iter()
                    .map(|band| band.row_range(off, off + len))
                    .collect();
                gemm_batch(1.0, &a, &b, 0.0, &mut cb);
            }
            drop(d_num);
            drop(d_kv);
            // Through the (fixed) random-feature maps back to raw
            // projection space (the 1/√dh undo is folded into the batched
            // alpha).
            {
                let phis: Vec<&Mat> = heads.iter().map(|hc| &hc.phi_q).collect();
                favor_feature_backward(
                    self.kernel,
                    &self.features,
                    &mut dphi_q,
                    &phis,
                    &c.qs,
                    scale,
                    dh,
                    off,
                    &mut dq,
                );
            }
            {
                let phis: Vec<&Mat> = heads.iter().map(|hc| &hc.phi_k).collect();
                favor_feature_backward(
                    self.kernel,
                    &self.features,
                    &mut dphi_k,
                    &phis,
                    &c.ks,
                    scale,
                    dh,
                    off,
                    &mut dk,
                );
            }
        }
        let dx = attn_proj_backward(&self.weights, &mut self.grads, ws, &c.x, &dq, &dk, &dv);
        Ok(dx)
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn scale_grads(&mut self, s: f32) {
        self.grads.scale(s);
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        attn_params(&self.weights)
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        attn_params_mut(&mut self.weights)
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn set_head_group(&mut self, heads: usize) {
        self.head_group = heads;
    }

    fn is_sequence_aware(&self) -> bool {
        true
    }
}

/// Streaming decode state for [`RandMultiHeadAttention`].
pub struct PerformerStream<'a> {
    attn: &'a RandMultiHeadAttention,
    /// Per-head running `φ(K)ᵀV` (m × d_h).
    kv: Vec<Mat>,
    /// Per-head running normalizer `φ(K)ᵀ1` (m).
    z: Vec<Vec<f32>>,
    tokens_seen: usize,
}

impl PerformerStream<'_> {
    /// Number of tokens absorbed so far.
    pub fn len(&self) -> usize {
        self.tokens_seen
    }

    pub fn is_empty(&self) -> bool {
        self.tokens_seen == 0
    }

    /// State size in bytes — constant in sequence length.
    pub fn state_bytes(&self) -> u64 {
        let m = self.attn.num_features as u64;
        let dh = self.attn.weights.head_dim() as u64;
        let h = self.attn.weights.num_heads as u64;
        h * (m * dh + m) * 4
    }

    /// Feed one token embedding `x_t (d,)`; returns the causal attention
    /// output for this position (attending to all tokens fed so far,
    /// including this one).
    pub fn step(&mut self, x_t: &[f32]) -> Vec<f32> {
        let w = &self.attn.weights;
        let d = w.embed_dim;
        assert_eq!(x_t.len(), d);
        let h = w.num_heads;
        let dh = w.head_dim();
        let scale = 1.0 / (dh as f32).sqrt();
        let x = Mat::from_vec(1, d, x_t.to_vec());
        let q = matmul(&x, &w.wq);
        let k = matmul(&x, &w.wk);
        let v = matmul(&x, &w.wv);
        let mut out = vec![0f32; d];
        for head in 0..h {
            let c0 = head * dh;
            let qh = Mat::from_vec(1, dh, q.row(0)[c0..c0 + dh].to_vec()).scale(scale);
            let kh = Mat::from_vec(1, dh, k.row(0)[c0..c0 + dh].to_vec()).scale(scale);
            let vh = &v.row(0)[c0..c0 + dh];
            let phi_q = self.attn.feature_map_with_stab(&qh, head, Some(0.0)); // 1 × m
            let phi_k = self.attn.feature_map_with_stab(&kh, head, Some(0.0)); // 1 × m
            // State update: kv += φ(k)ᵀ·v ; z += φ(k).
            let kv = &mut self.kv[head];
            for (j, &pk) in phi_k.row(0).iter().enumerate() {
                self.z[head][j] += pk;
                let row = kv.row_mut(j);
                for (dst, &vv) in row.iter_mut().zip(vh) {
                    *dst += pk * vv;
                }
            }
            // Output: φ(q)·kv / (φ(q)·z).
            let pq = phi_q.row(0);
            let denom: f32 = pq
                .iter()
                .zip(&self.z[head])
                .map(|(&a, &b)| a * b)
                .sum::<f32>()
                .max(1e-9);
            let orow = &mut out[c0..c0 + dh];
            for (j, &pqj) in pq.iter().enumerate() {
                let kvrow = self.kv[head].row(j);
                for (o, &s) in orow.iter_mut().zip(kvrow) {
                    *o += pqj * s;
                }
            }
            for o in orow.iter_mut() {
                *o /= denom;
            }
        }
        self.tokens_seen += 1;
        // Output projection.
        matmul(&Mat::from_vec(1, d, out), &w.wo).into_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_error;
    use crate::rng::Philox;

    #[test]
    fn dense_attention_rows_are_convex_combinations() {
        // With Wv = I and softmax rows summing to 1, each head output row
        // lies in the convex hull of V rows — check value bounds instead:
        // output of softmax(scores)·V has entries ≤ max|V|.
        let mut rng = Philox::seeded(131);
        let w = AttnWeights::random(16, 4, &mut rng);
        let mha = MultiHeadAttention::new(w);
        let x = Mat::randn(12, 16, &mut rng);
        let ctx = ForwardCtx::new();
        let y = mha.forward(&x, &ctx).unwrap();
        assert_eq!(y.shape(), (12, 16));
        assert!(ctx.mem().peak_bytes() > 0);
        assert_eq!(ctx.mem().live_bytes(), 0, "all temporaries released");
        // Inference scratch returned to the arena for the next call.
        assert!(ctx.workspace().pooled() > 0);
    }

    #[test]
    fn performer_approximates_dense_softmax() {
        // With plenty of random features the Performer output should land
        // near exact attention (loose tolerance — it's a Monte-Carlo method).
        let mut rng = Philox::seeded(132);
        let w = AttnWeights::random(8, 1, &mut rng);
        let x = Mat::randn(10, 8, &mut rng).scale(0.3); // small norms: RF approx is accurate
        let dense = MultiHeadAttention::new(w.clone());
        let ctx = ForwardCtx::new();
        let y_exact = dense.forward(&x, &ctx).unwrap();
        let perf = RandMultiHeadAttention::new(w, 2048, KernelKind::Softmax, 5);
        let y_rand = perf.forward(&x, &ctx).unwrap();
        let err = rel_error(&y_rand, &y_exact);
        assert!(err < 0.5, "performer deviates: rel {err}");
    }

    #[test]
    fn performer_memory_linear_dense_quadratic() {
        let mut rng = Philox::seeded(133);
        let w = AttnWeights::random(32, 4, &mut rng);
        let measure_dense = |n: usize| {
            let x = Mat::randn(n, 32, &mut Philox::seeded(1));
            let ctx = ForwardCtx::new();
            MultiHeadAttention::new(w.clone()).forward(&x, &ctx).unwrap();
            ctx.mem().peak_bytes()
        };
        let measure_perf = |n: usize| {
            let x = Mat::randn(n, 32, &mut Philox::seeded(1));
            let ctx = ForwardCtx::new();
            RandMultiHeadAttention::new(w.clone(), 16, KernelKind::Softmax, 2)
                .forward(&x, &ctx)
                .unwrap();
            ctx.mem().peak_bytes()
        };
        // Dense grows ~4× when n doubles; performer ~2×.
        let (d1, d2) = (measure_dense(64), measure_dense(128));
        let (p1, p2) = (measure_perf(64), measure_perf(128));
        let dense_ratio = d2 as f64 / d1 as f64;
        let perf_ratio = p2 as f64 / p1 as f64;
        assert!(dense_ratio > 3.0, "dense ratio {dense_ratio}");
        assert!(perf_ratio < 2.5, "performer ratio {perf_ratio}");
    }

    #[test]
    fn dense_oom_performer_survives() {
        // A budget that the quadratic path exceeds but the linear one fits —
        // the Figure-3 "x" marker scenario.
        let mut rng = Philox::seeded(134);
        let w = AttnWeights::random(32, 8, &mut rng);
        let n = 256;
        let x = Mat::randn(n, 32, &mut rng);
        let budget = 2 * 1024 * 1024; // 2 MiB
        let ctx_d = ForwardCtx::with_budget(budget);
        let dense_res = MultiHeadAttention::new(w.clone()).forward(&x, &ctx_d);
        assert!(dense_res.is_err(), "dense should exceed 2 MiB at n=256,h=8");
        let ctx_p = ForwardCtx::with_budget(budget);
        let perf_res =
            RandMultiHeadAttention::new(w, 32, KernelKind::Softmax, 3).forward(&x, &ctx_p);
        assert!(perf_res.is_ok(), "performer must fit the same budget");
    }

    #[test]
    fn repeated_inference_forwards_reuse_workspace_buffers() {
        // Steady state: the second forward draws every scratch block from
        // the arena the first one filled, so the pooled count stops
        // growing — the allocation-free hot path, observable.
        let mut rng = Philox::seeded(138);
        let w = AttnWeights::random(32, 4, &mut rng);
        let mha = MultiHeadAttention::new(w.clone());
        let perf = RandMultiHeadAttention::new(w, 16, KernelKind::Softmax, 2);
        let x = Mat::randn(40, 32, &mut rng);
        let ctx = ForwardCtx::new();
        let y1 = mha.forward(&x, &ctx).unwrap();
        let after_first = ctx.workspace().pooled();
        let y2 = mha.forward(&x, &ctx).unwrap();
        assert_eq!(after_first, ctx.workspace().pooled(), "no new buffers");
        assert_eq!(y1.data(), y2.data(), "reuse must not change results");
        let p1 = perf.forward(&x, &ctx).unwrap();
        let after_perf = ctx.workspace().pooled();
        let p2 = perf.forward(&x, &ctx).unwrap();
        assert_eq!(after_perf, ctx.workspace().pooled(), "no new buffers");
        assert_eq!(p1.data(), p2.data(), "reuse must not change results");
    }

    #[test]
    fn head_group_chunking_is_bitwise_invisible() {
        // Chunking only bounds how many heads' scratch is alive at once —
        // per-head products are independent in gemm_batch, so any group
        // size must reproduce the all-heads result bit for bit, including
        // a group that does not divide h.
        let mut rng = Philox::seeded(139);
        let w = AttnWeights::random(32, 4, &mut rng);
        let x = Mat::randn(24, 32, &mut rng);
        let ctx = ForwardCtx::new();
        let full_dense = MultiHeadAttention::new(w.clone()).forward(&x, &ctx).unwrap();
        let full_perf = RandMultiHeadAttention::new(w.clone(), 16, KernelKind::Softmax, 4)
            .forward(&x, &ctx)
            .unwrap();
        for g in [1usize, 2, 3, 4, 99] {
            let dense = MultiHeadAttention::new(w.clone()).with_head_group(g);
            assert_eq!(
                dense.forward(&x, &ctx).unwrap().data(),
                full_dense.data(),
                "dense, group {g}"
            );
            let perf = RandMultiHeadAttention::new(w.clone(), 16, KernelKind::Softmax, 4)
                .with_head_group(g);
            assert_eq!(
                perf.forward(&x, &ctx).unwrap().data(),
                full_perf.data(),
                "performer, group {g}"
            );
        }
        // The knob is also reachable through the Module trait (the serve
        // tier config applies it model-wide), and training forwards are
        // unaffected by it (they run un-chunked by design).
        let mut dense: Box<dyn Module> = Box::new(MultiHeadAttention::new(w.clone()));
        dense.set_head_group(2);
        assert_eq!(dense.forward(&x, &ctx).unwrap().data(), full_dense.data());
        let chunked = MultiHeadAttention::new(w).with_head_group(1);
        let (yt, _cache) = chunked.forward_train(&x, &ctx).unwrap();
        assert_eq!(yt.data(), full_dense.data());
    }

    #[test]
    fn head_group_chunking_bounds_peak_memory() {
        // A budget the all-heads forward exceeds but the chunked one
        // fits: the serving-tier scenario the knob exists for.
        let mut rng = Philox::seeded(140);
        let w = AttnWeights::random(32, 8, &mut rng);
        let n = 128;
        let x = Mat::randn(n, 32, &mut rng);
        // Dense peak ≈ 4·n·d + group·n·n floats; with n=128, d=32 that is
        // 64 KiB + group·64 KiB. Budget 320 KiB: all 8 heads (576 KiB)
        // exceed it, groups of 2 (192 KiB) fit.
        let budget = 320 * 1024;
        let full = MultiHeadAttention::new(w.clone());
        assert!(full.forward(&x, &ForwardCtx::with_budget(budget)).is_err());
        let chunked = MultiHeadAttention::new(w.clone()).with_head_group(2);
        let y = chunked
            .forward(&x, &ForwardCtx::with_budget(budget))
            .unwrap();
        assert_eq!(y.shape(), (n, 32));
        // Performer: per-head state is (2·n·m + m·dh + m) floats; with
        // m=64 that is ~65 KiB per head. Budget 200 KiB: 8 heads at once
        // (~522 KiB + 64 KiB projections) exceed it, one head at a time
        // fits.
        let budget = 200 * 1024;
        let full = RandMultiHeadAttention::new(w.clone(), 64, KernelKind::Softmax, 5);
        assert!(full.forward(&x, &ForwardCtx::with_budget(budget)).is_err());
        let chunked =
            RandMultiHeadAttention::new(w, 64, KernelKind::Softmax, 5).with_head_group(1);
        let y = chunked
            .forward(&x, &ForwardCtx::with_budget(budget))
            .unwrap();
        assert_eq!(y.shape(), (n, 32));
    }

    #[test]
    fn streaming_matches_causal_reference() {
        // The t-th streamed output must equal linear attention computed
        // over the prefix 0..=t with the same (stab=0) feature map.
        let mut rng = Philox::seeded(136);
        let (d, h, m, n) = (16usize, 2usize, 32usize, 10usize);
        let w = AttnWeights::random(d, h, &mut rng);
        let attn = RandMultiHeadAttention::new(w.clone(), m, KernelKind::Softmax, 11);
        let x = Mat::randn(n, d, &mut rng).scale(0.4);
        let mut stream = attn.start_stream();
        let dh = d / h;
        let scale = 1.0 / (dh as f32).sqrt();
        let q = crate::linalg::matmul(&x, &w.wq);
        let k = crate::linalg::matmul(&x, &w.wk);
        let v = crate::linalg::matmul(&x, &w.wv);
        for t in 0..n {
            let got = stream.step(x.row(t));
            // Reference: per head, φ over prefix with stab 0.
            let mut pre = Mat::zeros(1, d);
            for head in 0..h {
                let c0 = head * dh;
                let qh = Mat::from_vec(1, dh, q.row(t)[c0..c0 + dh].to_vec()).scale(scale);
                let pq = attn.feature_map_with_stab(&qh, head, Some(0.0));
                let mut num = vec![0f64; dh];
                let mut den = 0f64;
                for s in 0..=t {
                    let kh =
                        Mat::from_vec(1, dh, k.row(s)[c0..c0 + dh].to_vec()).scale(scale);
                    let pk = attn.feature_map_with_stab(&kh, head, Some(0.0));
                    let dot: f64 = pq
                        .row(0)
                        .iter()
                        .zip(pk.row(0))
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum();
                    den += dot;
                    for (nv, &vv) in num.iter_mut().zip(&v.row(s)[c0..c0 + dh]) {
                        *nv += dot * vv as f64;
                    }
                }
                for (j, nv) in num.iter().enumerate() {
                    pre.set(0, c0 + j, (nv / den.max(1e-12)) as f32);
                }
            }
            let want = crate::linalg::matmul(&pre, &w.wo);
            for (a, b) in got.iter().zip(want.row(0)) {
                assert!(
                    (a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "t={t}: {a} vs {b}"
                );
            }
        }
        assert_eq!(stream.len(), n);
    }

    #[test]
    fn streaming_state_is_constant_size() {
        let mut rng = Philox::seeded(137);
        let w = AttnWeights::random(32, 4, &mut rng);
        let attn = RandMultiHeadAttention::new(w, 64, KernelKind::Relu, 2);
        let mut stream = attn.start_stream();
        let s0 = stream.state_bytes();
        let x = Mat::randn(100, 32, &mut rng);
        for t in 0..100 {
            stream.step(x.row(t));
        }
        assert_eq!(stream.state_bytes(), s0, "state must not grow with n");
        assert_eq!(stream.len(), 100);
    }

    #[test]
    fn relu_kernel_runs() {
        let mut rng = Philox::seeded(135);
        let w = AttnWeights::random(16, 2, &mut rng);
        let x = Mat::randn(20, 16, &mut rng);
        let ctx = ForwardCtx::new();
        let y = RandMultiHeadAttention::new(w, 24, KernelKind::Relu, 7)
            .forward(&x, &ctx)
            .unwrap();
        assert_eq!(y.shape(), (20, 16));
        assert!(y.data().iter().all(|v| v.is_finite()));
    }
}
