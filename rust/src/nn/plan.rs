//! The sketching subsystem: [`Sketchable`] + [`SketchPlan`].
//!
//! A [`SketchPlan`] is the *single* compression path of the crate — the
//! paper's `SKAutoTuner(copy_weights=True).apply_best_params()` and the
//! one-layer convenience [`super::Model::sketchify`] both go through it.
//! A plan is a list of rules, each pairing a [`LayerSelector`] with the
//! `(num_terms, low_rank)` to apply:
//!
//! ```
//! use panther::nn::{LayerSelector, Linear, Model, SketchPlan};
//! use panther::rng::Philox;
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut rng = Philox::seeded(0);
//! let mut model = Model::new();
//! model.add("ffn.fc1", Linear::random(64, 64, &mut rng))?;
//! model.add("ffn.fc2", Linear::random(64, 64, &mut rng))?;
//! let report = SketchPlan::new()
//!     .select(LayerSelector::by_regex(r"ffn\.fc\d")?)
//!     .with(1, 8)
//!     .seed(7)
//!     .apply(&mut model)?;
//! assert_eq!(report.converted.len(), 2);
//! assert!(report.params_after < report.params_before);
//! # Ok(()) }
//! ```
//!
//! Which dense layer becomes which sketched layer is *not* decided by a
//! `match` over an enum of layer types: each dense layer implements
//! [`Sketchable`] and builds its own replacement, so new layer pairs plug
//! in without touching this file.

use super::attention::{KernelKind, MultiHeadAttention, RandMultiHeadAttention};
use super::conv::{Conv2d, SKConv2d};
use super::linear::{Linear, SKLinear};
use super::model::{LayerSelector, Model};
use super::module::Module;
use crate::rng::Philox;
use anyhow::{anyhow, ensure, Result};

/// A dense layer that can build its sketched drop-in replacement.
///
/// `low_rank` is the per-term rank `k` for linear/conv layers and the
/// random-feature count `m` for attention (which ignores `num_terms`) —
/// the same convention the paper's `LayerConfig` uses.
pub trait Sketchable {
    /// Build the sketched replacement at `(num_terms, low_rank)`,
    /// compressing the trained weights (`copy_weights=True` semantics).
    fn sketchify(&self, num_terms: usize, low_rank: usize, seed: u64) -> Box<dyn Module>;
}

impl Sketchable for Linear {
    fn sketchify(&self, num_terms: usize, low_rank: usize, seed: u64) -> Box<dyn Module> {
        let mut rng = Philox::seeded(seed);
        Box::new(SKLinear::from_dense(self, num_terms, low_rank, &mut rng))
    }
}

impl Sketchable for Conv2d {
    fn sketchify(&self, num_terms: usize, low_rank: usize, seed: u64) -> Box<dyn Module> {
        let mut rng = Philox::seeded(seed);
        Box::new(SKConv2d::from_dense(self, num_terms, low_rank, &mut rng))
    }
}

impl Sketchable for MultiHeadAttention {
    fn sketchify(&self, _num_terms: usize, low_rank: usize, seed: u64) -> Box<dyn Module> {
        Box::new(RandMultiHeadAttention::new(
            self.weights.clone(),
            low_rank,
            KernelKind::Softmax,
            seed,
        ))
    }
}

/// One selector → `(num_terms, low_rank)` rule of a plan.
struct Rule {
    selector: LayerSelector,
    params: Option<(usize, usize)>,
}

/// Builder for a model-compression pass.
///
/// Rules apply in insertion order; a layer converted by an earlier rule is
/// no longer sketchable and lands in [`CompressionReport::skipped`] if a
/// later rule matches it again. Per-layer randomness is derived
/// deterministically from the plan seed and the layer *name*, so results
/// do not depend on registry order.
#[derive(Default)]
pub struct SketchPlan {
    rules: Vec<Rule>,
    seed: u64,
    /// First builder misuse seen, reported by `apply` (the builder methods
    /// return `Self`, so they can't error in place).
    misuse: Option<&'static str>,
}

impl SketchPlan {
    /// Empty plan (seed 0).
    pub fn new() -> Self {
        Self::default()
    }

    /// Start a new rule for the layers matching `selector`. Follow with
    /// [`SketchPlan::with`] to set the rule's `(num_terms, low_rank)`.
    pub fn select(mut self, selector: LayerSelector) -> Self {
        self.rules.push(Rule {
            selector,
            params: None,
        });
        self
    }

    /// Set `(num_terms, low_rank)` for the most recent
    /// [`SketchPlan::select`] rule. Exactly one `with` per `select` —
    /// anything else is reported as an error by [`SketchPlan::apply`].
    pub fn with(mut self, num_terms: usize, low_rank: usize) -> Self {
        match self.rules.last_mut() {
            Some(rule) if rule.params.is_some() => {
                self.misuse
                    .get_or_insert("with(..) called twice for one select(..) rule");
            }
            Some(rule) => rule.params = Some((num_terms, low_rank)),
            None => {
                self.misuse
                    .get_or_insert("with(..) called before any select(..) rule");
            }
        }
        self
    }

    /// Base seed for the per-layer sketch randomness.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Apply every rule to `model`, replacing matched dense layers with
    /// their sketched counterparts in place. Errors on a malformed plan or
    /// a selector that matches nothing (a typo'd layer name should fail
    /// loudly, not silently compress nothing). Every rule is validated and
    /// resolved against the *pre-plan* model before the first replacement,
    /// so a failing plan never half-compresses the model (the replaced
    /// dense weights would be unrecoverable).
    pub fn apply(&self, model: &mut Model) -> Result<CompressionReport> {
        if let Some(misuse) = self.misuse {
            anyhow::bail!("malformed SketchPlan: {misuse}");
        }
        ensure!(!self.rules.is_empty(), "SketchPlan has no rules");
        let mut resolved = Vec::with_capacity(self.rules.len());
        for (ri, rule) in self.rules.iter().enumerate() {
            let (num_terms, low_rank) = rule.params.ok_or_else(|| {
                anyhow!("SketchPlan rule {ri} has no (num_terms, low_rank); call .with(..) after .select(..)")
            })?;
            ensure!(
                num_terms > 0 && low_rank > 0,
                "SketchPlan rule {ri}: num_terms and low_rank must be positive"
            );
            let names = model.select(&rule.selector);
            ensure!(!names.is_empty(), "SketchPlan rule {ri} matched no layers");
            resolved.push((num_terms, low_rank, names));
        }
        let params_before = model.total_params();
        let mut converted = Vec::new();
        let mut skipped = Vec::new();
        for (num_terms, low_rank, names) in resolved {
            for name in names {
                let outcome = {
                    let module = model
                        .get(&name)
                        .ok_or_else(|| anyhow!("selected layer {name} disappeared"))?;
                    let from = module.type_name();
                    let before = module.param_count();
                    match module.as_sketchable() {
                        Some(dense) => {
                            let seed = derive_seed(self.seed, &name);
                            Some((dense.sketchify(num_terms, low_rank, seed), from, before))
                        }
                        None => {
                            skipped.push(SkippedLayer {
                                name: name.clone(),
                                type_name: from.to_string(),
                                reason: "not sketchable (already sketched?)".to_string(),
                            });
                            None
                        }
                    }
                };
                if let Some((replacement, from, before)) = outcome {
                    let to = replacement.type_name().to_string();
                    let after = replacement.param_count();
                    model.replace(&name, replacement)?;
                    converted.push(LayerReport {
                        name,
                        from: from.to_string(),
                        to,
                        params_before: before,
                        params_after: after,
                    });
                }
            }
        }
        Ok(CompressionReport {
            converted,
            skipped,
            params_before,
            params_after: model.total_params(),
        })
    }
}

/// Stable per-layer seed: FNV-1a over the layer name, mixed with the plan
/// seed. Independent of registry order and of how many rules precede the
/// layer's rule.
fn derive_seed(base: u64, name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in name.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h ^ base
}

/// What happened to one converted layer.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Dotted layer path.
    pub name: String,
    /// Type name before conversion (e.g. `Linear`).
    pub from: String,
    /// Type name after conversion (e.g. `SKLinear`).
    pub to: String,
    /// Stored parameters before conversion.
    pub params_before: usize,
    /// Stored parameters after conversion.
    pub params_after: usize,
}

impl LayerReport {
    /// Size of the sketched layer relative to the dense one.
    pub fn ratio(&self) -> f64 {
        self.params_after as f64 / self.params_before.max(1) as f64
    }
}

/// A matched layer the plan could not convert.
#[derive(Debug, Clone)]
pub struct SkippedLayer {
    /// Dotted layer path.
    pub name: String,
    /// The layer's type name.
    pub type_name: String,
    /// Why it was skipped.
    pub reason: String,
}

/// Per-layer and whole-model outcome of [`SketchPlan::apply`].
#[derive(Debug, Clone)]
pub struct CompressionReport {
    /// Layers replaced by their sketched counterparts, in conversion order.
    pub converted: Vec<LayerReport>,
    /// Layers matched by a rule but left untouched.
    pub skipped: Vec<SkippedLayer>,
    /// Whole-model parameter count before the plan ran.
    pub params_before: usize,
    /// Whole-model parameter count after.
    pub params_after: usize,
}

impl CompressionReport {
    /// Whole-model size after / before.
    pub fn ratio(&self) -> f64 {
        self.params_after as f64 / self.params_before.max(1) as f64
    }

    /// Parameters eliminated by the plan.
    pub fn params_saved(&self) -> usize {
        self.params_before.saturating_sub(self.params_after)
    }
}

impl std::fmt::Display for CompressionReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "compression: {} -> {} params ({:.1}% of dense, {} layers converted, {} skipped)",
            self.params_before,
            self.params_after,
            self.ratio() * 100.0,
            self.converted.len(),
            self.skipped.len()
        )?;
        for c in &self.converted {
            writeln!(
                f,
                "  {:<32} {:>10} -> {:<10} {:>10} -> {:>8} params ({:.1}%)",
                c.name,
                c.from,
                c.to,
                c.params_before,
                c.params_after,
                c.ratio() * 100.0
            )?;
        }
        for s in &self.skipped {
            writeln!(f, "  {:<32} skipped ({}): {}", s.name, s.type_name, s.reason)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::AttnWeights;
    use crate::nn::conv::ConvShape;

    fn toy_model() -> Model {
        let mut rng = Philox::seeded(77);
        let mut m = Model::new();
        m.add("enc.ffn.fc1", Linear::random(32, 64, &mut rng)).unwrap();
        m.add("enc.ffn.fc2", Linear::random(64, 32, &mut rng)).unwrap();
        m.add(
            "enc.conv",
            Conv2d::random(
                ConvShape {
                    c_in: 3,
                    c_out: 8,
                    kernel: 3,
                    image: 8,
                    padding: 1,
                },
                &mut rng,
            ),
        )
        .unwrap();
        m.add(
            "enc.attn",
            MultiHeadAttention::new(AttnWeights::random(16, 4, &mut rng)),
        )
        .unwrap();
        m
    }

    #[test]
    fn plan_converts_matched_layers_and_reports() {
        let mut m = toy_model();
        let before = m.total_params();
        let report = SketchPlan::new()
            .select(LayerSelector::by_regex(r"ffn\.fc\d").unwrap())
            .with(1, 4)
            .seed(3)
            .apply(&mut m)
            .unwrap();
        assert_eq!(report.converted.len(), 2);
        assert!(report.skipped.is_empty());
        assert_eq!(report.params_before, before);
        assert_eq!(report.params_after, m.total_params());
        assert!(report.params_after < report.params_before);
        assert!(report.ratio() < 1.0);
        assert_eq!(m.get("enc.ffn.fc1").unwrap().type_name(), "SKLinear");
        assert_eq!(m.get("enc.ffn.fc2").unwrap().type_name(), "SKLinear");
        assert_eq!(m.get("enc.conv").unwrap().type_name(), "Conv2d");
        // The report renders without panicking and mentions the layers.
        let text = format!("{report}");
        assert!(text.contains("enc.ffn.fc1"));
    }

    #[test]
    fn multi_rule_plan_with_per_rule_params() {
        let mut m = toy_model();
        let report = SketchPlan::new()
            .select(LayerSelector::by_type("Linear"))
            .with(2, 4)
            .select(LayerSelector::by_type("Conv2d"))
            .with(1, 6)
            .select(LayerSelector::by_names(&["enc.attn"]))
            .with(1, 32)
            .apply(&mut m)
            .unwrap();
        assert_eq!(report.converted.len(), 4);
        assert_eq!(m.get("enc.conv").unwrap().type_name(), "SKConv2d");
        assert_eq!(
            m.get("enc.attn").unwrap().type_name(),
            "RandMultiheadAttention"
        );
    }

    #[test]
    fn resketching_is_skipped_not_fatal() {
        let mut m = toy_model();
        let sel = || LayerSelector::by_names(&["enc.ffn.fc1"]);
        SketchPlan::new().select(sel()).with(1, 4).apply(&mut m).unwrap();
        let report = SketchPlan::new()
            .select(sel())
            .with(1, 4)
            .apply(&mut m)
            .unwrap();
        assert!(report.converted.is_empty());
        assert_eq!(report.skipped.len(), 1);
        assert_eq!(report.skipped[0].name, "enc.ffn.fc1");
    }

    #[test]
    fn failing_plan_leaves_model_untouched() {
        // A later rule's error must not leave earlier rules applied — the
        // replaced dense weights would be gone.
        let mut m = toy_model();
        let before = m.total_params();
        let err = SketchPlan::new()
            .select(LayerSelector::by_type("Linear"))
            .with(1, 4)
            .select(LayerSelector::by_names(&["missing"]))
            .with(1, 8)
            .apply(&mut m);
        assert!(err.is_err());
        assert_eq!(m.total_params(), before);
        assert_eq!(m.get("enc.ffn.fc1").unwrap().type_name(), "Linear");
    }

    #[test]
    fn malformed_plans_error() {
        let mut m = toy_model();
        // No rules.
        assert!(SketchPlan::new().apply(&mut m).is_err());
        // with() before select().
        assert!(SketchPlan::new().with(1, 4).apply(&mut m).is_err());
        // Two with() for one select().
        assert!(SketchPlan::new()
            .select(LayerSelector::by_type("Linear"))
            .with(1, 4)
            .with(2, 8)
            .apply(&mut m)
            .is_err());
        // select() without with().
        assert!(SketchPlan::new()
            .select(LayerSelector::by_type("Linear"))
            .apply(&mut m)
            .is_err());
        // Selector matching nothing.
        assert!(SketchPlan::new()
            .select(LayerSelector::by_names(&["missing"]))
            .with(1, 4)
            .apply(&mut m)
            .is_err());
        // Zero rank.
        assert!(SketchPlan::new()
            .select(LayerSelector::by_type("Linear"))
            .with(1, 0)
            .apply(&mut m)
            .is_err());
    }

    #[test]
    fn per_layer_seeds_are_order_independent() {
        // Same plan applied to two models that register layers in opposite
        // order produces identical sketched weights per layer.
        let mut rng = Philox::seeded(88);
        let fc1 = Linear::random(16, 16, &mut rng);
        let fc2 = Linear::random(16, 16, &mut rng);
        let mut ma = Model::new();
        ma.add("a.fc1", fc1.clone()).unwrap();
        ma.add("a.fc2", fc2.clone()).unwrap();
        let mut mb = Model::new();
        mb.add("a.fc2", fc2).unwrap();
        mb.add("a.fc1", fc1).unwrap();
        let plan = || {
            SketchPlan::new()
                .select(LayerSelector::by_type("Linear"))
                .with(1, 4)
                .seed(9)
        };
        plan().apply(&mut ma).unwrap();
        plan().apply(&mut mb).unwrap();
        let sda = ma.get("a.fc1").unwrap().state_dict();
        let sdb = mb.get("a.fc1").unwrap().state_dict();
        assert_eq!(sda, sdb, "sketch must not depend on registry order");
    }
}
