//! Cholesky factorization. CholeskyQR (and therefore CQRRPT) reduces tall
//! QR to the Cholesky of the small Gram matrix `AᵀA`; failure of this
//! factorization is precisely the signal CQRRPT uses to detect that its
//! preconditioner did not make `A` well-conditioned enough.

use super::Mat;

/// Cholesky failure: the matrix was not (numerically) positive definite.
#[derive(Debug, Clone, PartialEq)]
pub struct CholError {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cholesky failed at pivot {}: diagonal value {}",
            self.pivot, self.value
        )
    }
}

impl std::error::Error for CholError {}

/// Lower Cholesky `A = L·Lᵀ` of a symmetric positive-definite matrix.
/// f64 accumulation throughout; returns Err on a non-positive pivot.
pub fn cholesky_lower(a: &Mat) -> Result<Mat, CholError> {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
    let mut l = vec![0f64; n * n];
    let ad = a.data();
    for j in 0..n {
        // Diagonal.
        let mut d = ad[j * n + j] as f64;
        for p in 0..j {
            d -= l[j * n + p] * l[j * n + p];
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(CholError { pivot: j, value: d });
        }
        let djs = d.sqrt();
        l[j * n + j] = djs;
        // Column below the diagonal.
        for i in (j + 1)..n {
            let mut s = ad[i * n + j] as f64;
            for p in 0..j {
                s -= l[i * n + p] * l[j * n + p];
            }
            l[i * n + j] = s / djs;
        }
    }
    Ok(Mat::from_vec(
        n,
        n,
        l.into_iter().map(|v| v as f32).collect(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn, rel_error};
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    #[test]
    fn reconstructs_spd() {
        let mut rng = Philox::seeded(31);
        let b = Mat::randn(20, 10, &mut rng);
        let a = matmul_tn(&b, &b); // AᵀA is SPD (b has full column rank w.p. 1)
        let l = cholesky_lower(&a).unwrap();
        let rec = matmul(&l, &l.transpose());
        assert!(rel_error(&rec, &a) < 1e-4);
    }

    #[test]
    fn lower_triangular_structure() {
        let mut rng = Philox::seeded(32);
        let b = Mat::randn(15, 6, &mut rng);
        let a = matmul_tn(&b, &b);
        let l = cholesky_lower(&a).unwrap();
        for i in 0..6 {
            for j in (i + 1)..6 {
                assert_eq!(l.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn fails_on_indefinite() {
        let mut a = Mat::eye(3);
        a.set(2, 2, -1.0);
        let err = cholesky_lower(&a).unwrap_err();
        assert_eq!(err.pivot, 2);
    }

    #[test]
    fn fails_on_singular() {
        let a = Mat::zeros(4, 4);
        assert!(cholesky_lower(&a).is_err());
    }

    #[test]
    fn identity_factors_to_identity() {
        let l = cholesky_lower(&Mat::eye(5)).unwrap();
        assert!(rel_error(&l, &Mat::eye(5)) < 1e-7);
    }

    #[test]
    fn property_gram_matrices_factor() {
        prop_check("chol-gram", 25, |g| {
            let n = g.usize(1..10);
            let m = n + g.usize(1..20);
            let b = Mat::randn(m, n, g.rng());
            let a = matmul_tn(&b, &b);
            let l = cholesky_lower(&a).expect("gram of full-rank tall matrix is SPD");
            let rec = matmul(&l, &l.transpose());
            assert!(rel_error(&rec, &a) < 1e-3);
        });
    }
}
