//! One-sided Jacobi SVD.
//!
//! RSVD reduces the SVD of a huge matrix to the SVD of a small `k×n` (or
//! `(k+p)×n`) core, so a robust dense SVD for modest sizes is all the
//! substrate needs. One-sided Jacobi is simple, numerically excellent
//! (it computes small singular values to high relative accuracy), and its
//! O(n³) per-sweep cost is irrelevant at these sizes.

use super::{matmul, Mat};

/// Thin SVD result: `A = U · diag(s) · Vᵀ` with `U: m×r`, `s: r`, `V: n×r`,
/// `r = min(m, n)`, singular values in non-increasing order.
pub struct Svd {
    pub u: Mat,
    pub s: Vec<f32>,
    pub v: Mat,
}

impl Svd {
    /// Reconstruct `A` (mostly for tests).
    pub fn reconstruct(&self) -> Mat {
        let r = self.s.len();
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            for j in 0..r {
                us.set(i, j, us.get(i, j) * self.s[j]);
            }
        }
        matmul(&us, &self.v.transpose())
    }

    /// Truncate to rank `k`.
    pub fn truncate(&self, k: usize) -> Svd {
        let k = k.min(self.s.len());
        Svd {
            u: self.u.slice(0, self.u.rows(), 0, k),
            s: self.s[..k].to_vec(),
            v: self.v.slice(0, self.v.rows(), 0, k),
        }
    }
}

/// One-sided Jacobi SVD (Hestenes). Orthogonalizes the columns of a working
/// copy of `A` by Jacobi rotations; on convergence the column norms are the
/// singular values and the accumulated rotations give `V`.
///
/// For `m < n` the factorization is computed on `Aᵀ` and swapped back.
pub fn svd_jacobi(a: &Mat) -> Svd {
    let (m, n) = a.shape();
    if m < n {
        let t = svd_jacobi(&a.transpose());
        return Svd {
            u: t.v,
            s: t.s,
            v: t.u,
        };
    }
    // Work in f64.
    let mut w: Vec<f64> = a.data().iter().map(|&x| x as f64).collect(); // m×n
    let mut v = vec![0f64; n * n];
    for j in 0..n {
        v[j * n + j] = 1.0;
    }
    let max_sweeps = 60;
    let eps = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                // Gram entries for columns p, q.
                let mut app = 0f64;
                let mut aqq = 0f64;
                let mut apq = 0f64;
                for i in 0..m {
                    let xp = w[i * n + p];
                    let xq = w[i * n + q];
                    app += xp * xp;
                    aqq += xq * xq;
                    apq += xp * xq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt() || apq == 0.0 {
                    continue;
                }
                off += apq.abs();
                // Jacobi rotation zeroing the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let xp = w[i * n + p];
                    let xq = w[i * n + q];
                    w[i * n + p] = c * xp - s * xq;
                    w[i * n + q] = s * xp + c * xq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-15 {
            break;
        }
    }
    // Column norms → singular values; normalize U columns.
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm = (0..m).map(|i| w[i * n + j].powi(2)).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut u = Mat::zeros(m, n);
    let mut vout = Mat::zeros(n, n);
    let mut s = Vec::with_capacity(n);
    for (dst, &(norm, src)) in sv.iter().enumerate() {
        s.push(norm as f32);
        if norm > 1e-300 {
            for i in 0..m {
                u.set(i, dst, (w[i * n + src] / norm) as f32);
            }
        }
        for i in 0..n {
            vout.set(i, dst, v[i * n + src] as f32);
        }
    }
    Svd { u, s, v: vout }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{ortho_error, rel_error};
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    #[test]
    fn reconstructs_random() {
        let mut rng = Philox::seeded(51);
        let a = Mat::randn(20, 12, &mut rng);
        let svd = svd_jacobi(&a);
        assert!(rel_error(&svd.reconstruct(), &a) < 1e-4);
        assert!(ortho_error(&svd.u) < 1e-4);
        assert!(ortho_error(&svd.v) < 1e-4);
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let mut rng = Philox::seeded(52);
        let a = Mat::randn(8, 25, &mut rng);
        let svd = svd_jacobi(&a);
        assert_eq!(svd.u.shape(), (8, 8));
        assert_eq!(svd.v.shape(), (25, 8));
        assert!(rel_error(&svd.reconstruct(), &a) < 1e-4);
    }

    #[test]
    fn known_singular_values() {
        // diag(3, 2, 1) embedded in 5×3.
        let mut a = Mat::zeros(5, 3);
        a.set(0, 0, 3.0);
        a.set(1, 1, 2.0);
        a.set(2, 2, 1.0);
        let svd = svd_jacobi(&a);
        assert!((svd.s[0] - 3.0).abs() < 1e-5);
        assert!((svd.s[1] - 2.0).abs() < 1e-5);
        assert!((svd.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn values_sorted_nonincreasing() {
        let mut rng = Philox::seeded(53);
        let a = Mat::randn(15, 15, &mut rng);
        let svd = svd_jacobi(&a);
        for w in svd.s.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn truncation_error_is_tail_energy() {
        // Eckart–Young: ‖A − A_k‖_F² = Σ_{i>k} σ_i².
        let mut rng = Philox::seeded(54);
        let a = Mat::randn(18, 10, &mut rng);
        let svd = svd_jacobi(&a);
        let k = 4;
        let ak = svd.truncate(k).reconstruct();
        let err2: f64 = {
            let d = a.sub(&ak);
            d.data().iter().map(|&x| (x as f64).powi(2)).sum()
        };
        let tail2: f64 = svd.s[k..].iter().map(|&s| (s as f64).powi(2)).sum();
        assert!(
            (err2 - tail2).abs() < 1e-3 * tail2.max(1e-9),
            "err2={err2} tail2={tail2}"
        );
    }

    #[test]
    fn property_reconstruction() {
        prop_check("svd-reconstruct", 15, |g| {
            let m = g.usize(1..15);
            let n = g.usize(1..15);
            let a = Mat::randn(m, n, g.rng());
            let svd = svd_jacobi(&a);
            assert!(rel_error(&svd.reconstruct(), &a) < 1e-3);
        });
    }

    #[test]
    fn rank_deficient() {
        let mut rng = Philox::seeded(55);
        let u = Mat::randn(12, 2, &mut rng);
        let v = Mat::randn(2, 9, &mut rng);
        let a = matmul(&u, &v);
        let svd = svd_jacobi(&a);
        assert!(svd.s[2] < 1e-4 * svd.s[0], "σ₃ should collapse: {:?}", &svd.s[..4]);
    }
}
