//! Row-major dense f32 matrix, plus the borrowed strided views
//! ([`MatRef`], [`MatMut`]) the packed GEMM kernel and its batched API
//! operate on.

use crate::rng::{fill_normal, Rng};
use std::marker::PhantomData;

/// Dense row-major matrix of f32. The storage layout matches what the PJRT
/// runtime exchanges with HLO executables, so host↔device copies are flat
/// memcpys.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![v; rows * cols],
        }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// I.i.d. standard normal entries.
    pub fn randn<R: Rng>(rows: usize, cols: usize, rng: &mut R) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        fill_normal(rng, &mut m.data);
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reshape in place to `rows × cols`, growing or shrinking the backing
    /// storage. Existing contents are NOT preserved meaningfully — callers
    /// (e.g. scratch-buffer reuse) must overwrite every element they read.
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Explicit transpose (cache-blocked).
    pub fn transpose(&self) -> Mat {
        const B: usize = 32;
        let mut t = Mat::zeros(self.cols, self.rows);
        for i0 in (0..self.rows).step_by(B) {
            for j0 in (0..self.cols).step_by(B) {
                for i in i0..(i0 + B).min(self.rows) {
                    for j in j0..(j0 + B).min(self.cols) {
                        t.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        t
    }

    /// Copy of column `j`.
    pub fn col(&self, j: usize) -> Vec<f32> {
        (0..self.rows).map(|i| self.get(i, j)).collect()
    }

    /// Sub-matrix copy `rows r0..r1, cols c0..c1`.
    pub fn slice(&self, r0: usize, r1: usize, c0: usize, c1: usize) -> Mat {
        assert!(r0 <= r1 && r1 <= self.rows && c0 <= c1 && c1 <= self.cols);
        let mut out = Mat::zeros(r1 - r0, c1 - c0);
        for i in r0..r1 {
            out.row_mut(i - r0)
                .copy_from_slice(&self.row(i)[c0..c1]);
        }
        out
    }

    /// Horizontal concatenation.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows);
        let mut out = Mat::zeros(self.rows, self.cols + other.cols);
        for i in 0..self.rows {
            out.row_mut(i)[..self.cols].copy_from_slice(self.row(i));
            out.row_mut(i)[self.cols..].copy_from_slice(other.row(i));
        }
        out
    }

    /// Vertical concatenation.
    pub fn vcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Mat::from_vec(self.rows + other.rows, self.cols, data)
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a + b)
            .collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!(self.shape(), other.shape());
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| a - b)
            .collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f32) -> Mat {
        let data = self.data.iter().map(|&a| a * s).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    /// In-place `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Mat) {
        assert_eq!(self.shape(), other.shape());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Matrix-vector product `A x` (f64 accumulation).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows)
            .map(|i| {
                self.row(i)
                    .iter()
                    .zip(x)
                    .map(|(&a, &b)| a as f64 * b as f64)
                    .sum::<f64>() as f32
            })
            .collect()
    }

    /// `Aᵀ x` without forming the transpose.
    pub fn matvec_t(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.rows);
        let mut out = vec![0f64; self.cols];
        for i in 0..self.rows {
            let xi = x[i] as f64;
            for (j, &a) in self.row(i).iter().enumerate() {
                out[j] += a as f64 * xi;
            }
        }
        out.into_iter().map(|v| v as f32).collect()
    }

    /// Apply a column permutation: `out[:, j] = self[:, perm[j]]`.
    pub fn permute_cols(&self, perm: &[usize]) -> Mat {
        assert_eq!(perm.len(), self.cols);
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = out.row_mut(i);
            for (j, &p) in perm.iter().enumerate() {
                dst[j] = src[p];
            }
        }
        out
    }

    /// Maximum absolute entry.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0f32, |m, &x| m.max(x.abs()))
    }

    /// Read-only strided view of the whole matrix (see [`MatRef`]).
    pub fn view(&self) -> MatRef<'_> {
        MatRef {
            data: &self.data,
            rows: self.rows,
            cols: self.cols,
            rs: self.cols,
            cs: 1,
            off: 0,
        }
    }

    /// Mutable view of the whole matrix (see [`MatMut`]).
    pub fn view_mut(&mut self) -> MatMut<'_> {
        MatMut {
            ptr: self.data.as_mut_ptr(),
            rows: self.rows,
            cols: self.cols,
            rs: self.cols,
            _life: PhantomData,
        }
    }

    /// Split into `cols/width` disjoint column bands of equal `width` —
    /// the per-head output views of the batched attention math. Requires
    /// `cols % width == 0`. The bands partition the storage element-wise,
    /// so they may be written concurrently from different workers.
    pub fn col_bands_mut(&mut self, width: usize) -> Vec<MatMut<'_>> {
        assert!(
            width > 0 && self.cols % width == 0,
            "col_bands_mut: {} cols not divisible by band width {width}",
            self.cols
        );
        let (rows, rs) = (self.rows, self.cols);
        let base = self.data.as_mut_ptr();
        (0..self.cols / width)
            .map(|b| MatMut {
                // SAFETY: band offsets stay inside the allocation whenever
                // any row exists; with zero rows no offset is formed (and
                // no element will ever be addressed through the view).
                ptr: if rows == 0 {
                    base
                } else {
                    unsafe { base.add(b * width) }
                },
                rows,
                cols: width,
                rs,
                _life: PhantomData,
            })
            .collect()
    }
}

/// Borrowed read-only strided view of f32 matrix storage — the operand
/// type of the packed GEMM ([`crate::linalg::gemm_batch`]). Generalized
/// (row, col) strides make a transposed operand ([`MatRef::t`]) or a
/// per-head column slice ([`MatRef::col_range`]) a free re-description of
/// the same storage: the GEMM packing resolves the layout, so callers
/// never materialize a transpose or copy a head slice.
#[derive(Clone, Copy, Debug)]
pub struct MatRef<'a> {
    pub(crate) data: &'a [f32],
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    /// Row stride (elements between vertically adjacent entries).
    pub(crate) rs: usize,
    /// Column stride (elements between horizontally adjacent entries).
    pub(crate) cs: usize,
    /// Offset of element (0, 0) into `data`.
    pub(crate) off: usize,
}

impl<'a> MatRef<'a> {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element (i, j).
    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[self.off + i * self.rs + j * self.cs]
    }

    /// Transposed view — free (swaps shape and strides).
    pub fn t(mut self) -> MatRef<'a> {
        std::mem::swap(&mut self.rows, &mut self.cols);
        std::mem::swap(&mut self.rs, &mut self.cs);
        self
    }

    /// View of columns `[c0, c1)` — free (offsets the base).
    pub fn col_range(mut self, c0: usize, c1: usize) -> MatRef<'a> {
        assert!(c0 <= c1 && c1 <= self.cols, "col_range out of bounds");
        self.off += c0 * self.cs;
        self.cols = c1 - c0;
        self
    }

    /// View of rows `[r0, r1)` — free (offsets the base).
    pub fn row_range(mut self, r0: usize, r1: usize) -> MatRef<'a> {
        assert!(r0 <= r1 && r1 <= self.rows, "row_range out of bounds");
        self.off += r0 * self.rs;
        self.rows = r1 - r0;
        self
    }
}

/// Mutable strided view of f32 matrix storage: rows are strided, columns
/// contiguous — the exact shape the GEMM microkernel writes. Constructed
/// only through [`Mat::view_mut`] / [`Mat::col_bands_mut`], which
/// guarantee element-disjoint ownership, so disjoint views may be written
/// concurrently from pool workers (hence the manual `Send`/`Sync`).
#[derive(Debug)]
pub struct MatMut<'a> {
    pub(crate) ptr: *mut f32,
    pub(crate) rows: usize,
    pub(crate) cols: usize,
    /// Row stride (elements between vertically adjacent entries).
    pub(crate) rs: usize,
    pub(crate) _life: PhantomData<&'a mut f32>,
}

// SAFETY: a MatMut owns its elements exclusively (constructor invariant),
// and the GEMM kernels partition each view into disjoint tiles before
// touching it from multiple workers.
unsafe impl Send for MatMut<'_> {}
unsafe impl Sync for MatMut<'_> {}

impl<'a> MatMut<'a> {
    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Narrow the view to rows `[r0, r1)` — free (offsets the base). Takes
    /// the view by value so element-disjoint ownership is preserved: the
    /// narrowed view *replaces* its parent rather than aliasing it. This is
    /// how the sequence-aware attention path writes one sequence's row block
    /// of a per-head column band ([`Mat::col_bands_mut`]) per GEMM item.
    pub fn row_range(mut self, r0: usize, r1: usize) -> MatMut<'a> {
        assert!(r0 <= r1 && r1 <= self.rows, "row_range out of bounds");
        if r1 > r0 && r0 > 0 {
            // SAFETY: the narrowed view is non-empty, so row r0 exists and
            // the offset stays inside the owned storage. (For an empty
            // narrowing no offset is formed — the base pointer of a banded
            // view plus r0·rs could land past the allocation.)
            self.ptr = unsafe { self.ptr.add(r0 * self.rs) };
        }
        self.rows = r1 - r0;
        self
    }

    /// Mutable slice of row `i`.
    #[inline]
    pub(crate) fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        // SAFETY: the view exclusively owns its elements and `i` is in
        // bounds; rows are `cols` contiguous elements at stride `rs`.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(i * self.rs), self.cols) }
    }

    /// Multiply every element by `s` (the batched GEMM's beta pre-pass).
    pub(crate) fn scale(&mut self, s: f32) {
        for i in 0..self.rows {
            for v in self.row_mut(i) {
                *v *= s;
            }
        }
    }

    /// Set every element to `v`.
    pub(crate) fn fill(&mut self, v: f32) {
        for i in 0..self.rows {
            self.row_mut(i).fill(v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn construction_and_indexing() {
        let m = Mat::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.get(1, 2), 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Philox::seeded(2);
        let a = Mat::randn(13, 7, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().get(3, 5), a.get(5, 3));
    }

    #[test]
    fn slicing_and_concat() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f32);
        let s = a.slice(1, 3, 2, 4);
        assert_eq!(s.shape(), (2, 2));
        assert_eq!(s.get(0, 0), 6.0);
        let h = a.slice(0, 4, 0, 2).hcat(&a.slice(0, 4, 2, 4));
        assert_eq!(h, a);
        let v = a.slice(0, 2, 0, 4).vcat(&a.slice(2, 4, 0, 4));
        assert_eq!(v, a);
    }

    #[test]
    fn arithmetic() {
        let a = Mat::filled(2, 2, 2.0);
        let b = Mat::filled(2, 2, 3.0);
        assert_eq!(a.add(&b), Mat::filled(2, 2, 5.0));
        assert_eq!(b.sub(&a), Mat::filled(2, 2, 1.0));
        assert_eq!(a.scale(4.0), Mat::filled(2, 2, 8.0));
        let mut c = a.clone();
        c.axpy(2.0, &b);
        assert_eq!(c, Mat::filled(2, 2, 8.0));
    }

    #[test]
    fn matvec_consistency() {
        let mut rng = Philox::seeded(3);
        let a = Mat::randn(6, 4, &mut rng);
        let x: Vec<f32> = (0..4).map(|i| i as f32).collect();
        let y = a.matvec(&x);
        let at = a.transpose();
        let y2 = at.matvec_t(&x);
        for (u, v) in y.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-5);
        }
    }

    #[test]
    fn permute_cols_identity_and_swap() {
        let a = Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f32);
        assert_eq!(a.permute_cols(&[0, 1, 2]), a);
        let p = a.permute_cols(&[2, 0, 1]);
        assert_eq!(p.row(0), &[2.0, 0.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_shape_mismatch_panics() {
        let _ = Mat::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn views_transpose_and_slice_without_copying() {
        let a = Mat::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        let v = a.view();
        assert_eq!(v.get(1, 2), a.get(1, 2));
        let t = v.t();
        assert_eq!((t.rows(), t.cols()), (4, 3));
        assert_eq!(t.get(2, 1), a.get(1, 2));
        let c = v.col_range(1, 3);
        assert_eq!((c.rows(), c.cols()), (3, 2));
        assert_eq!(c.get(2, 0), a.get(2, 1));
        let r = v.row_range(1, 3).col_range(2, 4).t();
        assert_eq!(r.get(0, 1), a.get(2, 2));
    }

    #[test]
    fn col_bands_partition_and_write_disjointly() {
        let mut a = Mat::zeros(2, 6);
        {
            let mut bands = a.col_bands_mut(2);
            assert_eq!(bands.len(), 3);
            for (bi, band) in bands.iter_mut().enumerate() {
                band.fill(bi as f32 + 1.0);
            }
            bands[1].scale(10.0);
        }
        assert_eq!(a.row(0), &[1.0, 1.0, 20.0, 20.0, 3.0, 3.0]);
        assert_eq!(a.row(1), &[1.0, 1.0, 20.0, 20.0, 3.0, 3.0]);
    }

    #[test]
    fn mat_mut_row_range_narrows_bands() {
        let mut a = Mat::zeros(4, 6);
        {
            let bands = a.col_bands_mut(2);
            for (bi, band) in bands.into_iter().enumerate() {
                // Write only rows [1, 3) of each band.
                let mut mid = band.row_range(1, 3);
                assert_eq!((mid.rows(), mid.cols()), (2, 2));
                mid.fill(bi as f32 + 1.0);
            }
        }
        for i in 0..4 {
            let expect = if (1..3).contains(&i) {
                vec![1.0, 1.0, 2.0, 2.0, 3.0, 3.0]
            } else {
                vec![0.0; 6]
            };
            assert_eq!(a.row(i), &expect[..], "row {i}");
        }
        // Empty narrowing at the end of a band is well-formed.
        let bands = a.col_bands_mut(2);
        for band in bands {
            let empty = band.row_range(4, 4);
            assert_eq!(empty.rows(), 0);
        }
    }
}
