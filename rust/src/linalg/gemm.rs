//! Blocked, multi-threaded GEMM built on a packed-panel microkernel.
//!
//! This is the dense baseline every figure bench compares against *and*
//! the substrate under every sketched op, so it is the one routine we tune
//! hard (see EXPERIMENTS.md §Perf). The layout follows the classic
//! BLIS/RandLAPACK recipe:
//!
//! - operands are **packed once per call** into panel buffers — A into
//!   MR-row panels, B into NR-column panels, both k-major — so the inner
//!   kernel reads two contiguous streams regardless of the caller's
//!   layout. Transposed operands and strided column slices (per-head
//!   views) resolve at packing time for free: no `B.transpose()` is ever
//!   materialized and no head slice is copied;
//! - the inner loop is a register-blocked **MR×NR = 8×4 microkernel**
//!   holding 32 independent f32 accumulators (breadth hides the FMA
//!   latency), flushed with fused `alpha·acc` store/accumulate every KC
//!   k-steps;
//! - work is parallelized over **(row-block × col-block) tiles** of C on
//!   the shared [`ThreadPool`]. Tiles never split the k dimension, so
//!   every C element accumulates its k terms in the same ascending order
//!   at any thread count — parallel results are bit-identical to serial.
//!   (The k-major order itself differs from the pre-packing kernel's and
//!   from a naive triple loop only in rounding; tests pin rel err ≤ 1e-5
//!   against an f64 oracle.)
//! - pack buffers come from a small process-wide pool, so steady-state
//!   calls allocate nothing.
//!
//! [`gemm_batch`] runs many independent problems through one dispatch:
//! packing is amortized per item and the tile set of *all* items feeds a
//! single `parallel_for`, which is how the per-head attention math gets
//! head-level parallelism and panel reuse in one call.

use super::mat::{Mat, MatMut, MatRef};
use crate::util::events::StageProfiler;
use crate::util::threadpool::ThreadPool;
use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Microkernel rows: 8 rows of C per register block.
const MR: usize = 8;
/// Microkernel cols: 4 cols of C per register block.
const NR: usize = 4;
/// Parallel tile height (rows of C per task); a multiple of MR.
const MC: usize = 64;
/// Parallel tile width (cols of C per task); a multiple of NR.
const NC: usize = 128;
/// Depth block: accumulators are flushed to C every KC k-steps, keeping
/// the live A/B panel slices L2-resident through the tile sweep.
const KC: usize = 256;
/// `m·k·n` below this, packing overhead beats its payoff — small products
/// stay on the direct kernels.
const PACK_MIN_WORK: usize = 32 * 32 * 32;
/// `m·k·n` below this, tile dispatch stays serial (pool overhead).
const PAR_MIN_WORK: usize = 64 * 64 * 64;
/// Retained pack buffers (two per concurrent GEMM call in steady state).
const PACK_POOL_MAX: usize = 8;

static POOL: OnceLock<ThreadPool> = OnceLock::new();
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(0); // 0 = default

/// Reusable packing storage shared by every GEMM call in the process:
/// buffers are taken at call start and returned at call end, so the
/// steady-state hot path performs no heap allocation. Packing overwrites
/// every slot (including panel padding), so recycled contents never leak
/// into results.
static PACK_POOL: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());

fn take_pack_buf(len: usize) -> Vec<f32> {
    let mut buf = crate::util::lock_ignore_poison(&PACK_POOL)
        .pop()
        .unwrap_or_default();
    buf.resize(len, 0.0);
    buf
}

fn give_pack_buf(buf: Vec<f32>) {
    let mut pool = crate::util::lock_ignore_poison(&PACK_POOL);
    if pool.len() < PACK_POOL_MAX {
        pool.push(buf);
    }
}

thread_local! {
    /// Stage profiler for GEMM phase attribution on this thread (see
    /// [`install_profiler`]). Thread-local so concurrent serve workers
    /// each attribute their own products; `None` (the default) costs one
    /// TLS read per packed dispatch — sub-threshold products never look.
    static PROFILER: RefCell<Option<Arc<StageProfiler>>> = const { RefCell::new(None) };
}

/// RAII guard from [`install_profiler`]: restores the previously
/// installed profiler (usually `None`) on drop.
pub struct GemmProfilerGuard {
    prev: Option<Arc<StageProfiler>>,
}

impl Drop for GemmProfilerGuard {
    fn drop(&mut self) {
        PROFILER.with(|slot| *slot.borrow_mut() = self.prev.take());
    }
}

/// Attribute this thread's packed-GEMM phases (`gemm/pack` panel packing,
/// `gemm/kernel` tile execution) to `p` until the returned guard drops.
/// Installed per forward by [`crate::nn::Model::forward`] when its
/// [`crate::nn::ForwardCtx`] carries a profiler; nestable (the guard
/// restores whatever was installed before).
pub fn install_profiler(p: Arc<StageProfiler>) -> GemmProfilerGuard {
    let prev = PROFILER.with(|slot| slot.borrow_mut().replace(p));
    GemmProfilerGuard { prev }
}

/// This thread's installed profiler, if any.
#[inline]
fn profiled() -> Option<Arc<StageProfiler>> {
    PROFILER.with(|slot| slot.borrow().clone())
}

/// Raw pointer to C's storage shared with pooled workers. Each call site
/// partitions C into disjoint ranges and every worker materializes `&mut`
/// slices only over the ranges it owns (never the whole buffer), so no two
/// live `&mut` alias.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Error from [`set_gemm_threads`]: the kernel pool was already
/// initialized (by an earlier GEMM call) with a different worker count,
/// so the request cannot take effect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GemmPoolError {
    /// The worker count that was requested.
    pub requested: usize,
    /// The worker count the pool is already running with.
    pub active: usize,
}

impl std::fmt::Display for GemmPoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "set_gemm_threads({}) after the kernel pool started with {} workers — \
             call it before the first matmul/gemm (or set PANTHER_GEMM_THREADS)",
            self.requested, self.active
        )
    }
}

impl std::error::Error for GemmPoolError {}

/// Configure GEMM parallelism. **Init-order contract:** the worker pool is
/// created lazily by the first multi-threaded product and is fixed for the
/// process lifetime, so this must be called early in `main`, before any
/// GEMM runs. A call after the pool exists returns [`GemmPoolError`]
/// (instead of the former silent no-op) unless the request resolves to
/// the active worker count. `Err` means the request did not take effect;
/// the knob behind it is never re-read once the pool exists. `1` disables
/// threading; `0` restores the default (`PANTHER_GEMM_THREADS` env
/// override, else machine size).
pub fn set_gemm_threads(n: usize) -> Result<(), GemmPoolError> {
    // What this request resolves to at init time.
    let want = if n == 0 { default_threads() } else { n.max(1) };
    if POOL.get().is_none() {
        GEMM_THREADS.store(n, Ordering::SeqCst);
    }
    // Check (again) after the store: if a first GEMM raced on another
    // thread and initialized the pool meanwhile, the store may have come
    // too late — report that instead of returning a false Ok. (An init
    // still in flight that read the old value and completes after this
    // check is not detectable from here; configure before spawning
    // kernel-using threads, as the contract above says.)
    match POOL.get() {
        None => Ok(()),
        Some(p) if p.num_workers() == want => {
            GEMM_THREADS.store(n, Ordering::SeqCst);
            Ok(())
        }
        Some(p) => Err(GemmPoolError {
            requested: n,
            active: p.num_workers(),
        }),
    }
}

/// The number of kernel workers in effect (initializes the pool if this is
/// the first query) — what bench reports record as `threads`.
pub fn gemm_threads() -> usize {
    pool().num_workers()
}

/// The worker count an unconfigured (`n = 0`) request resolves to: the
/// `PANTHER_GEMM_THREADS` env override (so whole test/bench runs can pin
/// the kernel thread count without code changes — CI runs a thread
/// matrix to catch parallel/serial divergence), else the machine size.
fn default_threads() -> usize {
    std::env::var("PANTHER_GEMM_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&v| v != 0)
        .unwrap_or_else(ThreadPool::default_size)
}

fn pool() -> &'static ThreadPool {
    POOL.get_or_init(|| {
        let n = GEMM_THREADS.load(Ordering::SeqCst);
        let n = if n == 0 { default_threads() } else { n };
        ThreadPool::new(n)
    })
}

// ---------------------------------------------------------------------------
// Packing
// ---------------------------------------------------------------------------

/// Pack an m×k operand view into MR-row panels: panel `pi` holds rows
/// `[pi·MR, pi·MR+MR)` k-major — `buf[pi·MR·k + p·MR + i] = A[pi·MR+i, p]`
/// — zero-padded past the last row so the microkernel never branches on m.
/// Strided/transposed views gather here, which is where the old per-call
/// `B.transpose()` cost went.
fn pack_a(a: &MatRef, buf: &mut [f32]) {
    let (m, k) = (a.rows(), a.cols());
    for pi in 0..m.div_ceil(MR) {
        let i0 = pi * MR;
        let live = MR.min(m - i0);
        let panel = &mut buf[pi * MR * k..(pi + 1) * MR * k];
        if a.rs == 1 && live == MR {
            // Unit row stride (a transposed row-major view): the MR lanes
            // of each k-step are contiguous in the source — straight copy,
            // no per-element bounds-checked gather.
            for p in 0..k {
                let src = a.off + i0 + p * a.cs;
                panel[p * MR..p * MR + MR].copy_from_slice(&a.data[src..src + MR]);
            }
            continue;
        }
        for p in 0..k {
            let dst = &mut panel[p * MR..p * MR + MR];
            for (i, d) in dst.iter_mut().enumerate().take(live) {
                *d = a.get(i0 + i, p);
            }
            for d in dst.iter_mut().skip(live) {
                *d = 0.0;
            }
        }
    }
}

/// Pack a k×n operand view into NR-column panels: panel `pj` holds columns
/// `[pj·NR, pj·NR+NR)` k-major — `buf[pj·NR·k + p·NR + j] = B[p, pj·NR+j]`
/// — zero-padded past the last column.
fn pack_b(b: &MatRef, buf: &mut [f32]) {
    let (k, n) = (b.rows(), b.cols());
    for pj in 0..n.div_ceil(NR) {
        let j0 = pj * NR;
        let live = NR.min(n - j0);
        let panel = &mut buf[pj * NR * k..(pj + 1) * NR * k];
        if b.cs == 1 && live == NR {
            // Unit column stride (the common non-transposed case): each
            // k-step's NR lanes are contiguous in the source row —
            // straight copy instead of a bounds-checked gather.
            for p in 0..k {
                let src = b.off + p * b.rs + j0;
                panel[p * NR..p * NR + NR].copy_from_slice(&b.data[src..src + NR]);
            }
            continue;
        }
        for p in 0..k {
            let dst = &mut panel[p * NR..p * NR + NR];
            for (j, d) in dst.iter_mut().enumerate().take(live) {
                *d = b.get(p, j0 + j);
            }
            for d in dst.iter_mut().skip(live) {
                *d = 0.0;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Microkernel + tiles
// ---------------------------------------------------------------------------

/// One MR×NR block of C from packed panels: `acc = Σ_p a[:,p]⊗b[p,:]` over
/// `kc` steps, then `C = alpha·acc` (`store`) or `C += alpha·acc`. The 32
/// independent accumulators keep the FMA pipes full; rows/cols beyond
/// `mr`/`nr` are computed against the pack's zero padding and simply not
/// written.
///
/// SAFETY: caller guarantees `cptr` addresses an `mr × nr` block with row
/// stride `rs` inside live C storage that it exclusively owns.
#[allow(clippy::too_many_arguments)]
#[inline]
unsafe fn micro_kernel(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    alpha: f32,
    cptr: *mut f32,
    rs: usize,
    mr: usize,
    nr: usize,
    store: bool,
) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let mut acc = [[0f32; NR]; MR];
    for p in 0..kc {
        // Fixed-size array views let the optimizer drop bounds checks and
        // vectorize the NR lane loop.
        let av: &[f32; MR] = ap[p * MR..p * MR + MR].try_into().unwrap();
        let bv: &[f32; NR] = bp[p * NR..p * NR + NR].try_into().unwrap();
        for (ai, arow) in acc.iter_mut().enumerate() {
            let a = av[ai];
            for (c, &b) in arow.iter_mut().zip(bv) {
                *c += a * b;
            }
        }
    }
    for (i, arow) in acc.iter().enumerate().take(mr) {
        let crow = std::slice::from_raw_parts_mut(cptr.add(i * rs), nr);
        if store {
            for (c, &a) in crow.iter_mut().zip(arow) {
                *c = alpha * a;
            }
        } else {
            for (c, &a) in crow.iter_mut().zip(arow) {
                *c += alpha * a;
            }
        }
    }
}

/// One (row-block × col-block) tile of C from fully packed operands.
/// `store` semantics apply to the first KC block only — later k blocks
/// always accumulate. Loop order keeps the current B panel slice (≤ KC·NR
/// floats) L1-resident across the row sweep.
#[allow(clippy::too_many_arguments)]
fn tile_job(
    tile: usize,
    col_tiles: usize,
    alpha: f32,
    ap: &[f32],
    bp: &[f32],
    overwrite: bool,
    c: &MatMut,
    m: usize,
    k: usize,
    n: usize,
) {
    let (ib, jb) = (tile / col_tiles, tile % col_tiles);
    let (i_lo, i_hi) = (ib * MC, (ib * MC + MC).min(m));
    let (j_lo, j_hi) = (jb * NC, (jb * NC + NC).min(n));
    let mut pc = 0;
    while pc < k {
        let kc = KC.min(k - pc);
        let store = overwrite && pc == 0;
        let mut jr = j_lo;
        while jr < j_hi {
            let nr = NR.min(j_hi - jr);
            let bpanel = &bp[(jr / NR) * NR * k + pc * NR..][..kc * NR];
            let mut ir = i_lo;
            while ir < i_hi {
                let mr = MR.min(i_hi - ir);
                let apanel = &ap[(ir / MR) * MR * k + pc * MR..][..kc * MR];
                // SAFETY: tiles partition C's rows and columns, so the
                // mr×nr block at (ir, jr) is exclusively this task's; the
                // pointer stays inside C (ir < m, jr < n).
                unsafe {
                    micro_kernel(
                        kc,
                        apanel,
                        bpanel,
                        alpha,
                        c.ptr.add(ir * c.rs + jr),
                        c.rs,
                        mr,
                        nr,
                        store,
                    )
                };
                ir += MR;
            }
            jr += NR;
        }
        pc += kc;
    }
}

/// `C ← alpha·A·B (+ C)` through the packed microkernel. With `overwrite`,
/// C's prior contents are never read (beta = 0 semantics — safe on
/// uninitialized/recycled buffers); otherwise the product accumulates
/// (the caller has already applied beta).
fn packed_gemm(alpha: f32, a: MatRef, b: MatRef, overwrite: bool, c: &mut MatMut) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    debug_assert_eq!(b.rows(), k);
    debug_assert_eq!((c.rows(), c.cols()), (m, n));
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        if overwrite {
            c.fill(0.0);
        }
        return;
    }
    let prof = profiled();
    let mut ap = take_pack_buf(m.div_ceil(MR) * MR * k);
    let mut bp = take_pack_buf(n.div_ceil(NR) * NR * k);
    let t_pack = prof.as_ref().map(|_| Instant::now());
    pack_a(&a, &mut ap);
    pack_b(&b, &mut bp);
    if let (Some(p), Some(t)) = (&prof, t_pack) {
        p.record("gemm/pack", t.elapsed());
    }
    let col_tiles = n.div_ceil(NC);
    let tiles = m.div_ceil(MC) * col_tiles;
    let t_kern = prof.as_ref().map(|_| Instant::now());
    if tiles == 1 || m * k * n < PAR_MIN_WORK {
        for t in 0..tiles {
            tile_job(t, col_tiles, alpha, &ap, &bp, overwrite, c, m, k, n);
        }
    } else {
        let cref = &*c;
        let (apr, bpr) = (&ap[..], &bp[..]);
        pool().parallel_for(tiles, move |t| {
            tile_job(t, col_tiles, alpha, apr, bpr, overwrite, cref, m, k, n);
        });
    }
    if let (Some(p), Some(t)) = (&prof, t_kern) {
        p.record("gemm/kernel", t.elapsed());
    }
    give_pack_buf(ap);
    give_pack_buf(bp);
}

/// True when the packed kernel is worth dispatching for an m×k·k×n
/// product (enough row reuse to amortize packing, enough work to matter).
#[inline]
fn use_packed(m: usize, k: usize, n: usize) -> bool {
    m >= MR && m * k * n >= PACK_MIN_WORK
}

// ---------------------------------------------------------------------------
// Public single-product entry points
// ---------------------------------------------------------------------------

/// `C = A · B`.
///
/// Large products run the packed-panel microkernel (B is packed into
/// column panels directly from its natural layout — the former per-call
/// `B.transpose()` materialization is gone); small ones run the direct
/// blocked axpy kernel, whose overhead-free start wins under the packing
/// threshold.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    if use_packed(m, k, n) {
        packed_gemm(1.0, a.view(), b.view(), true, &mut c.view_mut());
    } else {
        gemm_into(a, b, 1.0, &mut c);
    }
    c
}

/// `C = Aᵀ · B` without materializing the transpose.
///
/// Aᵀ·B with A row-major is a k-major sweep: accumulate outer products of
/// A's rows into C. Parallelized over disjoint tiles of the output (row
/// blocks × column strips — each worker owns its own C entries, so the
/// sweep is race-free), with a serial fallback for small problems. Tiling
/// both dimensions keeps skinny outputs parallel too (Gram matrices
/// `AᵀA` with few columns but a huge k are the common decomposition shape).
/// Every C entry accumulates its k terms in the same ascending-p order
/// regardless of tile layout, so results are bit-identical to the serial
/// sweep at any thread count.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    // Tile sizes: a C tile plus B's strip stay cache resident through the
    // k sweep.
    const JB: usize = 128;
    const RB: usize = 16;
    let work = k * m * n;
    let col_strips = n.div_ceil(JB);
    let row_blocks = m.div_ceil(RB);
    let ntiles = col_strips * row_blocks;
    if work < PAR_MIN_WORK || ntiles == 1 {
        tn_tile(a, b, c.data_mut().as_mut_ptr(), (0, m), (0, n), n);
        return c;
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let cptr = &cptr;
    pool().parallel_for(ntiles, move |t| {
        let (rb, sb) = (t / col_strips, t % col_strips);
        let rows = (rb * RB, ((rb + 1) * RB).min(m));
        let cols = (sb * JB, ((sb + 1) * JB).min(n));
        tn_tile(a, b, cptr.0, rows, cols, n);
    });
    c
}

/// `C[i0..i1, j0..j1] += (Aᵀ·B)[i0..i1, j0..j1]` on raw C storage
/// (row-major, n cols).
///
/// Callers pass disjoint tiles per thread; the only `&mut` slices formed
/// are over this tile's own row segments.
fn tn_tile(
    a: &Mat,
    b: &Mat,
    cbase: *mut f32,
    (i0, i1): (usize, usize),
    (j0, j1): (usize, usize),
    n: usize,
) {
    let k = a.rows();
    for p in 0..k {
        let arow = a.row(p);
        let brow = &b.row(p)[j0..j1];
        for i in i0..i1 {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            // SAFETY: [i·n+j0, i·n+j1) lies inside C and belongs exclusively
            // to this tile (tiles partition C's rows and columns).
            let crow =
                unsafe { std::slice::from_raw_parts_mut(cbase.add(i * n + j0), j1 - j0) };
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
}

/// `C = A · Bᵀ` without materializing the transpose.
///
/// Large products go through the packed kernel (the transposed operand is
/// resolved by the packing gather); small ones run the NT dot kernel —
/// both operand rows contiguous, 8 independent partial sums per dot.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    if use_packed(m, k, n) {
        packed_gemm(1.0, a.view(), b.view().t(), true, &mut c.view_mut());
    } else {
        for i in 0..m {
            nt_row(a.row(i), b, c.row_mut(i));
        }
    }
    c
}

/// The NT dot kernel: 8 independent f32 partial sums (keeps the FMA pipes
/// full; a single accumulator serializes on the add latency), scalar tail.
#[inline]
fn nt_dot(arow: &[f32], brow: &[f32]) -> f32 {
    let mut acc = [0f32; 8];
    let chunks = arow.len() / 8 * 8;
    let (ah, at) = arow.split_at(chunks);
    let (bh, bt) = brow.split_at(chunks);
    for (av, bv) in ah.chunks_exact(8).zip(bh.chunks_exact(8)) {
        for p in 0..8 {
            acc[p] += av[p] * bv[p];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// One output row of the NT product: `crow[j] = arow · b.row(j)`.
#[inline]
fn nt_row(arow: &[f32], b: &Mat, crow: &mut [f32]) {
    for (j, cv) in crow.iter_mut().enumerate() {
        *cv = nt_dot(arow, b.row(j));
    }
}

/// General `C = alpha·A·B + beta·C`.
///
/// `alpha·A·B` accumulates directly into `C` — no m×n temporary. With
/// `beta == 0` the packed kernel's store path writes C outright (prior
/// contents, e.g. a recycled workspace buffer, are never read). Kernel
/// dispatch mirrors [`matmul`].
pub fn gemm(alpha: f32, a: &Mat, b: &Mat, beta: f32, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    if alpha == 0.0 || !use_packed(m, k, n) {
        if beta == 0.0 {
            // Never *read* C under beta = 0 (0·NaN would leak recycled
            // workspace garbage) — overwrite outright.
            c.data_mut().fill(0.0);
        } else if beta != 1.0 {
            for v in c.data_mut() {
                *v *= beta;
            }
        }
        if alpha != 0.0 {
            gemm_into(a, b, alpha, c);
        }
        return;
    }
    if beta == 0.0 {
        packed_gemm(alpha, a.view(), b.view(), true, &mut c.view_mut());
    } else {
        if beta != 1.0 {
            for v in c.data_mut() {
                *v *= beta;
            }
        }
        packed_gemm(alpha, a.view(), b.view(), false, &mut c.view_mut());
    }
}

// ---------------------------------------------------------------------------
// Batched API
// ---------------------------------------------------------------------------

/// Strided batched GEMM: `C_i = alpha·A_i·B_i + beta·C_i` for every item.
///
/// Operands are [`MatRef`] views, so the common batch shapes are free to
/// describe: per-head column slices of one shared projection
/// (`q.view().col_range(c0, c1)`), transposed factors (`.t()`), and
/// per-head output bands of one shared matrix ([`Mat::col_bands_mut`]).
/// Items may have heterogeneous shapes.
///
/// Every item is packed once, then the tiles of *all* items are dispatched
/// through a single `parallel_for` — head-level parallelism and panel
/// reuse compose instead of running h sequential products. Like [`gemm`],
/// `beta == 0` means C is written without ever being read, and k is never
/// split across workers, so results are thread-count independent.
pub fn gemm_batch(alpha: f32, a: &[MatRef], b: &[MatRef], beta: f32, c: &mut [MatMut]) {
    assert_eq!(a.len(), b.len(), "gemm_batch: operand count mismatch");
    assert_eq!(a.len(), c.len(), "gemm_batch: output count mismatch");
    for i in 0..a.len() {
        assert_eq!(
            a[i].cols(),
            b[i].rows(),
            "gemm_batch item {i}: inner dims {} vs {}",
            a[i].cols(),
            b[i].rows()
        );
        assert_eq!(
            (c[i].rows(), c[i].cols()),
            (a[i].rows(), b[i].cols()),
            "gemm_batch item {i}: output shape"
        );
    }
    if beta != 0.0 && beta != 1.0 {
        for ci in c.iter_mut() {
            ci.scale(beta);
        }
    }
    if alpha == 0.0 {
        if beta == 0.0 {
            for ci in c.iter_mut() {
                ci.fill(0.0);
            }
        }
        return;
    }
    let overwrite = beta == 0.0;
    // Per-item geometry + pack-buffer layout (prefix offsets into two
    // shared buffers, one take/give round-trip each).
    struct Item {
        m: usize,
        k: usize,
        n: usize,
        col_tiles: usize,
        ap: (usize, usize),
        bp: (usize, usize),
    }
    let mut items = Vec::with_capacity(a.len());
    let (mut ap_len, mut bp_len) = (0usize, 0usize);
    let (mut tiles_total, mut work_total) = (0usize, 0usize);
    let mut tile_off = Vec::with_capacity(a.len() + 1);
    for i in 0..a.len() {
        let (m, k, n) = (a[i].rows(), a[i].cols(), b[i].cols());
        if k == 0 && overwrite {
            c[i].fill(0.0);
        }
        let mut degenerate = m == 0 || n == 0 || k == 0;
        if !degenerate && m * k * n < PACK_MIN_WORK {
            // Sub-threshold item: packing overhead beats its payoff (the
            // same break-even the single-product entry points honor) —
            // run the direct strided kernel right here, serially, and
            // give the item no pack range or tiles.
            small_item(alpha, &a[i], &b[i], overwrite, &c[i]);
            degenerate = true;
        }
        let (asz, bsz) = if degenerate {
            (0, 0)
        } else {
            (m.div_ceil(MR) * MR * k, n.div_ceil(NR) * NR * k)
        };
        let col_tiles = n.div_ceil(NC);
        let tiles = if degenerate {
            0
        } else {
            m.div_ceil(MC) * col_tiles
        };
        tile_off.push(tiles_total);
        items.push(Item {
            m,
            k,
            n,
            col_tiles,
            ap: (ap_len, ap_len + asz),
            bp: (bp_len, bp_len + bsz),
        });
        ap_len += asz;
        bp_len += bsz;
        tiles_total += tiles;
        work_total += m * k * n;
    }
    tile_off.push(tiles_total);
    if tiles_total == 0 {
        return;
    }
    let prof = profiled();
    let mut ap_buf = take_pack_buf(ap_len);
    let mut bp_buf = take_pack_buf(bp_len);
    let t_pack = prof.as_ref().map(|_| Instant::now());
    for (i, it) in items.iter().enumerate() {
        if it.ap.1 > it.ap.0 {
            pack_a(&a[i], &mut ap_buf[it.ap.0..it.ap.1]);
            pack_b(&b[i], &mut bp_buf[it.bp.0..it.bp.1]);
        }
    }
    if let (Some(p), Some(t)) = (&prof, t_pack) {
        p.record("gemm/pack", t.elapsed());
    }
    let c_views: &[MatMut] = c;
    let run = |t: usize| {
        // The item owning global tile t (tile_off is sorted ascending).
        let i = tile_off.partition_point(|&o| o <= t) - 1;
        let it = &items[i];
        tile_job(
            t - tile_off[i],
            it.col_tiles,
            alpha,
            &ap_buf[it.ap.0..it.ap.1],
            &bp_buf[it.bp.0..it.bp.1],
            overwrite,
            &c_views[i],
            it.m,
            it.k,
            it.n,
        );
    };
    let t_kern = prof.as_ref().map(|_| Instant::now());
    if tiles_total == 1 || work_total < PAR_MIN_WORK {
        for t in 0..tiles_total {
            run(t);
        }
    } else {
        pool().parallel_for(tiles_total, run);
    }
    if let (Some(p), Some(t)) = (&prof, t_kern) {
        p.record("gemm/kernel", t.elapsed());
    }
    give_pack_buf(ap_buf);
    give_pack_buf(bp_buf);
}

// ---------------------------------------------------------------------------
// Small-product kernels
// ---------------------------------------------------------------------------

/// Direct strided kernel for sub-threshold [`gemm_batch`] items:
/// `C_i = alpha·A·B (+ C_i)` straight off the views, i-k-j order with a
/// contiguous C row as the accumulate target — no packing, no dispatch.
/// With `overwrite` the row is zero-filled first (C's prior contents are
/// never read, matching the packed store path's contract).
fn small_item(alpha: f32, a: &MatRef, b: &MatRef, overwrite: bool, c: &MatMut) {
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    for i in 0..m {
        // SAFETY: rows of a MatMut are exclusively owned `cols`-element
        // spans at stride `rs` (constructor invariant); this loop touches
        // each row once from one thread.
        let crow = unsafe { std::slice::from_raw_parts_mut(c.ptr.add(i * c.rs), n) };
        if overwrite {
            crow.fill(0.0);
        }
        for p in 0..k {
            let av = alpha * a.get(i, p);
            if av == 0.0 {
                continue;
            }
            for (j, cv) in crow.iter_mut().enumerate() {
                *cv += av * b.get(p, j);
            }
        }
    }
}

/// Direct blocked kernel for products under the packing threshold:
/// `C += alpha·A·B`, i-k-j order with KC depth blocking and a 4-wide
/// unrolled axpy inner loop. Serial — under the threshold, dispatch
/// overhead exceeds the work.
fn gemm_into(a: &Mat, b: &Mat, alpha: f32, c: &mut Mat) {
    let m = a.rows();
    let k = a.cols();
    let n = b.cols();
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let cbase = c.data_mut().as_mut_ptr();
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for i in 0..m {
            let arow = a.row(i);
            // SAFETY: row i of C, borrowed one at a time; `a` and `b` are
            // distinct allocations from `c` (no aliasing).
            let crow = unsafe { std::slice::from_raw_parts_mut(cbase.add(i * n), n) };
            for p in p0..p1 {
                let aip = alpha * arow[p];
                if aip == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                // 4-wide unroll; the tail handled separately.
                let chunks = n / 4 * 4;
                let (bh, bt) = brow.split_at(chunks);
                let (ch, ct) = crow.split_at_mut(chunks);
                for (cv, bv) in ch.chunks_exact_mut(4).zip(bh.chunks_exact(4)) {
                    cv[0] += aip * bv[0];
                    cv[1] += aip * bv[1];
                    cv[2] += aip * bv[2];
                    cv[3] += aip * bv[3];
                }
                for (cv, bv) in ct.iter_mut().zip(bt) {
                    *cv += aip * bv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0f64;
                for p in 0..a.cols() {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Philox::seeded(4);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (7, 7, 7), (16, 1, 16)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            let r = matmul_naive(&a, &b);
            assert!(super::super::rel_error(&c, &r) < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn matches_naive_blocked_sizes() {
        let mut rng = Philox::seeded(5);
        // Cross the MC/KC/NC block boundaries and leave MR/NR tails.
        let a = Mat::randn(130, 300, &mut rng);
        let b = Mat::randn(300, 70, &mut rng);
        assert!(super::super::rel_error(&matmul(&a, &b), &matmul_naive(&a, &b)) < 1e-5);
    }

    #[test]
    fn packed_kernel_edge_tails_match_naive() {
        // Shapes chosen to sit just above the packing threshold with every
        // kind of ragged edge: rows not divisible by MR, cols not by NR,
        // k crossing a KC boundary.
        let mut rng = Philox::seeded(21);
        for &(m, k, n) in &[
            (9usize, 500usize, 9usize),
            (8, 257, 17),
            (65, 64, 129),
            (127, 300, 5),
        ] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let err = super::super::rel_error(&matmul(&a, &b), &matmul_naive(&a, &b));
            assert!(err < 1e-5, "({m},{k},{n}): rel {err}");
        }
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Philox::seeded(6);
        let a = Mat::randn(40, 30, &mut rng);
        let b = Mat::randn(40, 20, &mut rng);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&a.transpose(), &b);
        assert!(super::super::rel_error(&c1, &c2) < 1e-5);

        let x = Mat::randn(25, 40, &mut rng);
        let y = Mat::randn(35, 40, &mut rng);
        let d1 = matmul_nt(&x, &y);
        let d2 = matmul(&x, &y.transpose());
        assert!(super::super::rel_error(&d1, &d2) < 1e-5);
    }

    #[test]
    fn tn_parallel_tiles_bit_identical_to_serial() {
        let mut rng = Philox::seeded(9);
        // 90 rows × 300 cols spans multiple 16-row blocks and 128-column
        // strips, and the work size crosses the parallel threshold, so this
        // exercises the pooled tile path.
        let a = Mat::randn(120, 90, &mut rng);
        let b = Mat::randn(120, 300, &mut rng);
        let c = matmul_tn(&a, &b);
        assert!(super::super::rel_error(&c, &matmul(&a.transpose(), &b)) < 1e-5);
        let mut serial = Mat::zeros(90, 300);
        tn_tile(&a, &b, serial.data_mut().as_mut_ptr(), (0, 90), (0, 300), 300);
        assert_eq!(c.data(), serial.data(), "tile layout changed the bits");
    }

    #[test]
    fn packed_parallel_tiles_bit_identical_to_serial() {
        // k is never split across tiles, so the packed kernel must produce
        // the same bits from the pooled tile sweep as from a serial one.
        let mut rng = Philox::seeded(22);
        let (m, k, n) = (130, 96, 150);
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let par = matmul(&a, &b); // above PAR_MIN_WORK → pooled tiles
        let mut ser = Mat::zeros(m, n);
        let mut ap = vec![0.0; m.div_ceil(MR) * MR * k];
        let mut bp = vec![0.0; n.div_ceil(NR) * NR * k];
        pack_a(&a.view(), &mut ap);
        pack_b(&b.view(), &mut bp);
        let col_tiles = n.div_ceil(NC);
        {
            let cv = &mut ser.view_mut();
            for t in 0..m.div_ceil(MC) * col_tiles {
                tile_job(t, col_tiles, 1.0, &ap, &bp, true, cv, m, k, n);
            }
        }
        assert_eq!(par.data(), ser.data(), "tile dispatch changed the bits");
    }

    #[test]
    fn tn_skinny_gram_shape_parallel_path_correct() {
        // Gram-matrix shape: huge k, few columns — row blocks carry the
        // parallelism. 40 output rows × 40 cols, k = 700 → work above the
        // serial threshold with a single column strip.
        let mut rng = Philox::seeded(10);
        let a = Mat::randn(700, 40, &mut rng);
        let g = matmul_tn(&a, &a);
        let reference = matmul(&a.transpose(), &a);
        assert!(super::super::rel_error(&g, &reference) < 1e-5);
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Philox::seeded(7);
        let a = Mat::randn(10, 12, &mut rng);
        let b = Mat::randn(12, 8, &mut rng);
        let mut c = Mat::filled(10, 8, 1.0);
        gemm(2.0, &a, &b, 0.5, &mut c);
        let expect = matmul(&a, &b).scale(2.0).add(&Mat::filled(10, 8, 0.5));
        assert!(super::super::rel_error(&c, &expect) < 1e-5);
    }

    #[test]
    fn gemm_alpha_beta_across_parallel_threshold() {
        // (200, 300, 70): m·k·n clears PAR_MIN_WORK — the pooled packed
        // path. (40, 50, 30): above the packing threshold but below the
        // parallel cutoff — the serial packed path. (6, 50, 30): m < 8 —
        // the direct axpy kernel with alpha folded in. All must agree with
        // the alpha·A·B + beta·C oracle built from naive parts.
        let mut rng = Philox::seeded(11);
        for &(m, k, n) in &[(200usize, 300usize, 70usize), (40, 50, 30), (6, 50, 30)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c0 = Mat::randn(m, n, &mut rng);
            let mut c = c0.clone();
            gemm(1.5, &a, &b, -0.5, &mut c);
            let expect = matmul_naive(&a, &b).scale(1.5).add(&c0.scale(-0.5));
            assert!(
                super::super::rel_error(&c, &expect) < 1e-4,
                "({m},{k},{n}): rel {}",
                super::super::rel_error(&c, &expect)
            );
        }
    }

    #[test]
    fn gemm_beta_zero_never_reads_c() {
        // beta = 0 must fully overwrite even NaN garbage — the contract
        // workspace-recycled buffers rely on. Both dispatch paths.
        let mut rng = Philox::seeded(13);
        for &(m, k, n) in &[(40usize, 60usize, 50usize), (4, 5, 6)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let mut c = Mat::filled(m, n, f32::NAN);
            gemm(1.0, &a, &b, 0.0, &mut c);
            let err = super::super::rel_error(&c, &matmul_naive(&a, &b));
            assert!(err < 1e-5, "({m},{k},{n}): rel {err}");
        }
    }

    #[test]
    fn gemm_alpha_zero_only_scales_c() {
        let mut rng = Philox::seeded(12);
        let a = Mat::randn(6, 5, &mut rng);
        let b = Mat::randn(5, 4, &mut rng);
        let c0 = Mat::randn(6, 4, &mut rng);
        let mut c = c0.clone();
        gemm(0.0, &a, &b, 2.0, &mut c);
        assert!(super::super::rel_error(&c, &c0.scale(2.0)) < 1e-6);
    }

    #[test]
    fn gemm_batch_matches_per_item_products() {
        // Heterogeneous shapes, shared-storage views, transposed operands,
        // and column-band outputs — the attention shapes.
        let mut rng = Philox::seeded(14);
        let (n, d, h) = (48usize, 32usize, 4usize);
        let dh = d / h;
        let q = Mat::randn(n, d, &mut rng);
        let k = Mat::randn(n, d, &mut rng);
        // Per-head scores: Qh · Khᵀ into independent Mats.
        let mut scores: Vec<Mat> = (0..h).map(|_| Mat::filled(n, n, f32::NAN)).collect();
        {
            let a: Vec<MatRef> = (0..h)
                .map(|i| q.view().col_range(i * dh, (i + 1) * dh))
                .collect();
            let b: Vec<MatRef> = (0..h)
                .map(|i| k.view().col_range(i * dh, (i + 1) * dh).t())
                .collect();
            let mut c: Vec<MatMut> = scores.iter_mut().map(|s| s.view_mut()).collect();
            gemm_batch(0.5, &a, &b, 0.0, &mut c);
        }
        for (i, s) in scores.iter().enumerate() {
            let qh = q.slice(0, n, i * dh, (i + 1) * dh);
            let kh = k.slice(0, n, i * dh, (i + 1) * dh);
            let want = matmul_naive(&qh, &kh.transpose()).scale(0.5);
            let err = super::super::rel_error(s, &want);
            assert!(err < 1e-5, "head {i}: rel {err}");
        }
        // Scores · Vh into column bands of one shared output.
        let v = Mat::randn(n, d, &mut rng);
        let mut out = Mat::zeros(n, d);
        {
            let a: Vec<MatRef> = scores.iter().map(|s| s.view()).collect();
            let b: Vec<MatRef> = (0..h)
                .map(|i| v.view().col_range(i * dh, (i + 1) * dh))
                .collect();
            let mut c = out.col_bands_mut(dh);
            gemm_batch(1.0, &a, &b, 0.0, &mut c);
        }
        for i in 0..h {
            let vh = v.slice(0, n, i * dh, (i + 1) * dh);
            let want = matmul_naive(&scores[i], &vh);
            let got = out.slice(0, n, i * dh, (i + 1) * dh);
            let err = super::super::rel_error(&got, &want);
            assert!(err < 1e-5, "band {i}: rel {err}");
        }
    }

    #[test]
    fn gemm_batch_beta_and_degenerate_items() {
        let mut rng = Philox::seeded(15);
        let a0 = Mat::randn(5, 7, &mut rng);
        let b0 = Mat::randn(7, 3, &mut rng);
        let c0_init = Mat::randn(5, 3, &mut rng);
        let mut c0 = c0_init.clone();
        // A k = 0 item under beta = 0 must come out zero-filled.
        let a1 = Mat::zeros(4, 0);
        let b1 = Mat::zeros(0, 2);
        let mut c1 = Mat::filled(4, 2, 7.0);
        {
            let a = [a0.view(), a1.view()];
            let b = [b0.view(), b1.view()];
            let mut c = [c0.view_mut(), c1.view_mut()];
            gemm_batch(2.0, &a, &b, 0.0, &mut c);
        }
        let want = matmul_naive(&a0, &b0).scale(2.0);
        assert!(super::super::rel_error(&c0, &want) < 1e-5);
        assert!(c1.data().iter().all(|&v| v == 0.0));
        // beta = 1 accumulates; beta = -1 negates then accumulates.
        let mut c2 = c0_init.clone();
        {
            let a = [a0.view()];
            let b = [b0.view()];
            let mut c = [c2.view_mut()];
            gemm_batch(1.0, &a, &b, -1.0, &mut c);
        }
        let want2 = matmul_naive(&a0, &b0).add(&c0_init.scale(-1.0));
        assert!(super::super::rel_error(&c2, &want2) < 1e-4);
    }

    #[test]
    fn set_gemm_threads_errors_after_pool_init() {
        // Force pool creation, then a conflicting late call must fail and
        // a matching one must be accepted.
        let active = gemm_threads();
        let err = set_gemm_threads(active + 1).expect_err("late resize must error");
        assert_eq!(err.active, active);
        assert_eq!(err.requested, active + 1);
        assert!(err.to_string().contains("set_gemm_threads"));
        assert!(set_gemm_threads(active).is_ok());
        // 0 = "the default": accepted post-init iff the pool already runs
        // at the resolved default size (true here — nothing reconfigured
        // the knob before the pool first initialized).
        assert_eq!(set_gemm_threads(0).is_ok(), active == default_threads());
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Philox::seeded(8);
        let a = Mat::randn(9, 9, &mut rng);
        let c = matmul(&a, &Mat::eye(9));
        assert!(super::super::rel_error(&c, &a) < 1e-6);
    }

    #[test]
    fn empty_dims() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        assert_eq!(matmul(&a, &b).shape(), (0, 3));
    }
}
