//! Blocked, multi-threaded GEMM.
//!
//! This is the dense baseline every figure bench compares against, so it is
//! the one routine we tune hard (see EXPERIMENTS.md §Perf): i-k-j loop order
//! over a packed B panel, 4-wide j unrolling for the autovectorizer, L2-size
//! blocking, and row-block parallelism over a shared thread pool.

use super::Mat;
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Row-block size (tuned; see EXPERIMENTS.md §Perf).
const MC: usize = 64;
/// Depth-block size.
const KC: usize = 256;

static POOL: OnceLock<ThreadPool> = OnceLock::new();
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(0); // 0 = default

/// Raw pointer to C's storage shared with pooled workers. Each call site
/// partitions C into disjoint ranges and every worker materializes `&mut`
/// slices only over the ranges it owns (never the whole buffer), so no two
/// live `&mut` alias.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Configure GEMM parallelism (takes effect before first use; after that the
/// pool is fixed — call early in `main`). 1 disables threading.
pub fn set_gemm_threads(n: usize) {
    GEMM_THREADS.store(n, Ordering::SeqCst);
}

fn pool() -> &'static ThreadPool {
    POOL.get_or_init(|| {
        let mut n = GEMM_THREADS.load(Ordering::SeqCst);
        if n == 0 {
            // Env override so whole test/bench runs can pin the kernel
            // thread count without code changes (CI runs a
            // PANTHER_GEMM_THREADS=1 lane to catch parallel/serial
            // divergence).
            n = std::env::var("PANTHER_GEMM_THREADS")
                .ok()
                .and_then(|v| v.parse().ok())
                .unwrap_or(0);
        }
        let n = if n == 0 {
            ThreadPool::default_size()
        } else {
            n
        };
        ThreadPool::new(n)
    })
}

/// `C = A · B`.
///
/// Large products are routed through an explicit transpose of `B` and the
/// NT dot kernel: the O(k·n) transpose is amortized over O(m·k·n) MACs and
/// the dot kernel sustains ~3.5× the axpy kernel's throughput on this CPU
/// (no store traffic in the inner loop) — see EXPERIMENTS.md §Perf #3.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul shape mismatch: {:?} x {:?}",
        a.shape(),
        b.shape()
    );
    let work = a.rows() * a.cols() * b.cols();
    // Transpose pays off once the GEMM dominates the O(k·n) reshuffle;
    // m ≥ 8 rows of reuse is the observed break-even.
    if a.rows() >= 8 && work >= 32 * 32 * 32 {
        return matmul_nt(a, &b.transpose());
    }
    let mut c = Mat::zeros(a.rows(), b.cols());
    gemm_into(a, b, 1.0, &mut c);
    c
}

/// `C = Aᵀ · B` without materializing the transpose.
///
/// Aᵀ·B with A row-major is a k-major sweep: accumulate outer products of
/// A's rows into C. Parallelized over disjoint tiles of the output (row
/// blocks × column strips — each worker owns its own C entries, so the
/// sweep is race-free), with a serial fallback for small problems. Tiling
/// both dimensions keeps skinny outputs parallel too (Gram matrices
/// `AᵀA` with few columns but a huge k are the common decomposition shape).
/// Every C entry accumulates its k terms in the same ascending-p order
/// regardless of tile layout, so results are bit-identical to the serial
/// sweep at any thread count.
pub fn matmul_tn(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows(), b.rows(), "matmul_tn shape mismatch");
    let (k, m) = a.shape();
    let n = b.cols();
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    // Tile sizes: a C tile plus B's strip stay cache resident through the
    // k sweep.
    const JB: usize = 128;
    const RB: usize = 16;
    let work = k * m * n;
    let col_strips = n.div_ceil(JB);
    let row_blocks = m.div_ceil(RB);
    let ntiles = col_strips * row_blocks;
    if work < 64 * 64 * 64 || ntiles == 1 {
        tn_tile(a, b, c.data_mut().as_mut_ptr(), (0, m), (0, n), n);
        return c;
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let cptr = &cptr;
    pool().parallel_for(ntiles, move |t| {
        let (rb, sb) = (t / col_strips, t % col_strips);
        let rows = (rb * RB, ((rb + 1) * RB).min(m));
        let cols = (sb * JB, ((sb + 1) * JB).min(n));
        tn_tile(a, b, cptr.0, rows, cols, n);
    });
    c
}

/// `C[i0..i1, j0..j1] += (Aᵀ·B)[i0..i1, j0..j1]` on raw C storage
/// (row-major, n cols).
///
/// Callers pass disjoint tiles per thread; the only `&mut` slices formed
/// are over this tile's own row segments.
fn tn_tile(
    a: &Mat,
    b: &Mat,
    cbase: *mut f32,
    (i0, i1): (usize, usize),
    (j0, j1): (usize, usize),
    n: usize,
) {
    let k = a.rows();
    for p in 0..k {
        let arow = a.row(p);
        let brow = &b.row(p)[j0..j1];
        for i in i0..i1 {
            let aip = arow[i];
            if aip == 0.0 {
                continue;
            }
            // SAFETY: [i·n+j0, i·n+j1) lies inside C and belongs exclusively
            // to this tile (tiles partition C's rows and columns).
            let crow =
                unsafe { std::slice::from_raw_parts_mut(cbase.add(i * n + j0), j1 - j0) };
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += aip * bv;
            }
        }
    }
}

/// `C = A · Bᵀ` without materializing the transpose.
///
/// NT is the dot-product layout (both operand rows contiguous), so the
/// kernel is 8 independent f32 partial sums per dot (keeps the FMA pipes
/// full; a single accumulator serializes on the add latency) with row-block
/// parallelism. This is the dense `Linear::forward` path the figure benches
/// compare against — see EXPERIMENTS.md §Perf for the before/after.
pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.cols(), "matmul_nt shape mismatch");
    let m = a.rows();
    let n = b.rows();
    let k = a.cols();
    let mut c = Mat::zeros(m, n);
    if m == 0 || n == 0 {
        return c;
    }
    let work = m * n * k;
    if work < 64 * 64 * 64 {
        for i in 0..m {
            nt_row(a.row(i), b, c.row_mut(i));
        }
        return c;
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let cptr = &cptr;
    let nblocks = m.div_ceil(MC);
    pool().parallel_for(nblocks, move |ib| {
        let i0 = ib * MC;
        let i1 = ((ib + 1) * MC).min(m);
        for i in i0..i1 {
            // SAFETY: row i belongs to this worker's block; row blocks
            // [i0, i1) are disjoint across ib, so no two live `&mut` alias.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
            nt_row(a.row(i), b, crow);
        }
    });
    c
}

/// The NT dot kernel: 8 independent f32 partial sums (keeps the FMA pipes
/// full; a single accumulator serializes on the add latency), scalar tail.
#[inline]
fn nt_dot(arow: &[f32], brow: &[f32]) -> f32 {
    let mut acc = [0f32; 8];
    let chunks = arow.len() / 8 * 8;
    let (ah, at) = arow.split_at(chunks);
    let (bh, bt) = brow.split_at(chunks);
    for (av, bv) in ah.chunks_exact(8).zip(bh.chunks_exact(8)) {
        for p in 0..8 {
            acc[p] += av[p] * bv[p];
        }
    }
    let mut s = (acc[0] + acc[4]) + (acc[1] + acc[5]) + (acc[2] + acc[6]) + (acc[3] + acc[7]);
    for (x, y) in at.iter().zip(bt) {
        s += x * y;
    }
    s
}

/// One output row of the NT product: `crow[j] = arow · b.row(j)`.
#[inline]
fn nt_row(arow: &[f32], b: &Mat, crow: &mut [f32]) {
    for (j, cv) in crow.iter_mut().enumerate() {
        *cv = nt_dot(arow, b.row(j));
    }
}

/// Accumulating variant: `crow[j] += alpha · (arow · b.row(j))`.
#[inline]
fn nt_row_accum(alpha: f32, arow: &[f32], b: &Mat, crow: &mut [f32]) {
    for (j, cv) in crow.iter_mut().enumerate() {
        *cv += alpha * nt_dot(arow, b.row(j));
    }
}

/// General `C = alpha·A·B + beta·C`.
///
/// The product accumulates `alpha·A·B` directly into `C` — no full m×n
/// temporary is materialized (the old `matmul` + `axpy` route allocated
/// one and traversed C twice). Kernel dispatch mirrors [`matmul`]: large
/// products transpose B once and accumulate through the fast NT dot
/// kernel; small ones run the blocked axpy kernel in place.
pub fn gemm(alpha: f32, a: &Mat, b: &Mat, beta: f32, c: &mut Mat) {
    assert_eq!(a.cols(), b.rows());
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    if beta != 1.0 {
        for v in c.data_mut() {
            *v *= beta;
        }
    }
    if alpha == 0.0 {
        return;
    }
    let work = a.rows() * a.cols() * b.cols();
    if a.rows() >= 8 && work >= 32 * 32 * 32 {
        gemm_nt_accum(a, &b.transpose(), alpha, c);
    } else {
        gemm_into(a, b, alpha, c);
    }
}

/// `C += alpha·A·Bᵀ` in the NT (dot-product) layout, parallel over row
/// blocks — the same kernel [`matmul`] routes large products through,
/// accumulating into C instead of materializing the product.
fn gemm_nt_accum(a: &Mat, bt: &Mat, alpha: f32, c: &mut Mat) {
    let m = a.rows();
    let n = bt.rows();
    let k = a.cols();
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let work = m * n * k;
    if work < 64 * 64 * 64 {
        for i in 0..m {
            nt_row_accum(alpha, a.row(i), bt, c.row_mut(i));
        }
        return;
    }
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let cptr = &cptr;
    let nblocks = m.div_ceil(MC);
    pool().parallel_for(nblocks, move |ib| {
        let i0 = ib * MC;
        let i1 = ((ib + 1) * MC).min(m);
        for i in i0..i1 {
            // SAFETY: row i belongs to this worker's block; row blocks
            // [i0, i1) are disjoint across ib, so no two live `&mut` alias.
            let crow = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i * n), n) };
            nt_row_accum(alpha, a.row(i), bt, crow);
        }
    });
}

/// Core blocked kernel: `C += alpha·A · B`, parallel over row blocks.
fn gemm_into(a: &Mat, b: &Mat, alpha: f32, c: &mut Mat) {
    let m = a.rows();
    let k = a.cols();
    let n = b.cols();
    if m == 0 || n == 0 || k == 0 {
        return;
    }
    let nblocks = m.div_ceil(MC);
    // Small problems: stay serial to avoid pool overhead.
    let work = m * n * k;
    if work < 64 * 64 * 64 || nblocks == 1 {
        let cbase = c.data_mut().as_mut_ptr();
        for ib in 0..nblocks {
            gemm_rows_raw(a, b, alpha, cbase, ib * MC, ((ib + 1) * MC).min(m));
        }
        return;
    }
    // Each worker writes a disjoint row range of C (the pool joins before
    // we return).
    let cptr = SendPtr(c.data_mut().as_mut_ptr());
    let cptr = &cptr;
    pool().parallel_for(nblocks, move |ib| {
        let i0 = ib * MC;
        let i1 = ((ib + 1) * MC).min(m);
        gemm_rows_raw(a, b, alpha, cptr.0, i0, i1);
    });
}

/// `C[i0..i1, :] += alpha·A[i0..i1, :] · B` on raw C storage (row-major,
/// n cols).
///
/// Callers pass disjoint `[i0, i1)` row blocks per thread; the only `&mut`
/// slices formed are over this block's own rows. `alpha` folds into the
/// per-(i,p) scalar, so the inner kernel is unchanged.
fn gemm_rows_raw(a: &Mat, b: &Mat, alpha: f32, cbase: *mut f32, i0: usize, i1: usize) {
    let k = a.cols();
    let n = b.cols();
    for p0 in (0..k).step_by(KC) {
        let p1 = (p0 + KC).min(k);
        for i in i0..i1 {
            let arow = a.row(i);
            // SAFETY: row i lies in [i0, i1), owned exclusively by this
            // block (row blocks partition C's rows).
            let crow = unsafe { std::slice::from_raw_parts_mut(cbase.add(i * n), n) };
            for p in p0..p1 {
                let aip = alpha * arow[p];
                if aip == 0.0 {
                    continue;
                }
                let brow = b.row(p);
                // 4-wide unroll; the tail handled separately.
                let chunks = n / 4 * 4;
                let (bh, bt) = brow.split_at(chunks);
                let (ch, ct) = crow.split_at_mut(chunks);
                for (cv, bv) in ch.chunks_exact_mut(4).zip(bh.chunks_exact(4)) {
                    cv[0] += aip * bv[0];
                    cv[1] += aip * bv[1];
                    cv[2] += aip * bv[2];
                    cv[3] += aip * bv[3];
                }
                for (cv, bv) in ct.iter_mut().zip(bt) {
                    *cv += aip * bv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows(), b.cols());
        for i in 0..a.rows() {
            for j in 0..b.cols() {
                let mut acc = 0f64;
                for p in 0..a.cols() {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    #[test]
    fn matches_naive_small() {
        let mut rng = Philox::seeded(4);
        for &(m, k, n) in &[(1, 1, 1), (3, 4, 5), (7, 7, 7), (16, 1, 16)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c = matmul(&a, &b);
            let r = matmul_naive(&a, &b);
            assert!(super::super::rel_error(&c, &r) < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn matches_naive_blocked_sizes() {
        let mut rng = Philox::seeded(5);
        // Cross the MC/KC block boundaries.
        let a = Mat::randn(130, 300, &mut rng);
        let b = Mat::randn(300, 70, &mut rng);
        assert!(super::super::rel_error(&matmul(&a, &b), &matmul_naive(&a, &b)) < 1e-5);
    }

    #[test]
    fn tn_and_nt_variants() {
        let mut rng = Philox::seeded(6);
        let a = Mat::randn(40, 30, &mut rng);
        let b = Mat::randn(40, 20, &mut rng);
        let c1 = matmul_tn(&a, &b);
        let c2 = matmul(&a.transpose(), &b);
        assert!(super::super::rel_error(&c1, &c2) < 1e-5);

        let x = Mat::randn(25, 40, &mut rng);
        let y = Mat::randn(35, 40, &mut rng);
        let d1 = matmul_nt(&x, &y);
        let d2 = matmul(&x, &y.transpose());
        assert!(super::super::rel_error(&d1, &d2) < 1e-5);
    }

    #[test]
    fn tn_parallel_tiles_bit_identical_to_serial() {
        let mut rng = Philox::seeded(9);
        // 90 rows × 300 cols spans multiple 16-row blocks and 128-column
        // strips, and the work size crosses the parallel threshold, so this
        // exercises the pooled tile path.
        let a = Mat::randn(120, 90, &mut rng);
        let b = Mat::randn(120, 300, &mut rng);
        let c = matmul_tn(&a, &b);
        assert!(super::super::rel_error(&c, &matmul(&a.transpose(), &b)) < 1e-5);
        let mut serial = Mat::zeros(90, 300);
        tn_tile(&a, &b, serial.data_mut().as_mut_ptr(), (0, 90), (0, 300), 300);
        assert_eq!(c.data(), serial.data(), "tile layout changed the bits");
    }

    #[test]
    fn tn_skinny_gram_shape_parallel_path_correct() {
        // Gram-matrix shape: huge k, few columns — row blocks carry the
        // parallelism. 40 output rows × 40 cols, k = 700 → work above the
        // serial threshold with a single column strip.
        let mut rng = Philox::seeded(10);
        let a = Mat::randn(700, 40, &mut rng);
        let g = matmul_tn(&a, &a);
        let reference = matmul(&a.transpose(), &a);
        assert!(super::super::rel_error(&g, &reference) < 1e-5);
    }

    #[test]
    fn gemm_alpha_beta() {
        let mut rng = Philox::seeded(7);
        let a = Mat::randn(10, 12, &mut rng);
        let b = Mat::randn(12, 8, &mut rng);
        let mut c = Mat::filled(10, 8, 1.0);
        gemm(2.0, &a, &b, 0.5, &mut c);
        let expect = matmul(&a, &b).scale(2.0).add(&Mat::filled(10, 8, 0.5));
        assert!(super::super::rel_error(&c, &expect) < 1e-5);
    }

    #[test]
    fn gemm_alpha_beta_across_parallel_threshold() {
        // (200, 300, 70): m spans several MC=64 row blocks and m·k·n
        // clears the 64³ cutoff — the pooled NT accumulate path.
        // (40, 50, 30): above the NT dispatch threshold but below the
        // parallel cutoff — the serial NT accumulate path. (6, 50, 30):
        // m < 8 — the blocked axpy kernel with alpha folded in. All must
        // agree with the alpha·A·B + beta·C oracle built from naive parts.
        let mut rng = Philox::seeded(11);
        for &(m, k, n) in &[(200usize, 300usize, 70usize), (40, 50, 30), (6, 50, 30)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            let c0 = Mat::randn(m, n, &mut rng);
            let mut c = c0.clone();
            gemm(1.5, &a, &b, -0.5, &mut c);
            let expect = matmul_naive(&a, &b).scale(1.5).add(&c0.scale(-0.5));
            assert!(
                super::super::rel_error(&c, &expect) < 1e-4,
                "({m},{k},{n}): rel {}",
                super::super::rel_error(&c, &expect)
            );
        }
    }

    #[test]
    fn gemm_alpha_zero_only_scales_c() {
        let mut rng = Philox::seeded(12);
        let a = Mat::randn(6, 5, &mut rng);
        let b = Mat::randn(5, 4, &mut rng);
        let c0 = Mat::randn(6, 4, &mut rng);
        let mut c = c0.clone();
        gemm(0.0, &a, &b, 2.0, &mut c);
        assert!(super::super::rel_error(&c, &c0.scale(2.0)) < 1e-6);
    }

    #[test]
    fn identity_is_noop() {
        let mut rng = Philox::seeded(8);
        let a = Mat::randn(9, 9, &mut rng);
        let c = matmul(&a, &Mat::eye(9));
        assert!(super::super::rel_error(&c, &a) < 1e-6);
    }

    #[test]
    fn empty_dims() {
        let a = Mat::zeros(0, 5);
        let b = Mat::zeros(5, 3);
        assert_eq!(matmul(&a, &b).shape(), (0, 3));
    }
}
