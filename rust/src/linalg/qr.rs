//! Householder QR: thin (economy) and column-pivoted variants.
//!
//! The column-pivoted factorization is the deterministic core CQRRPT runs on
//! the *sketch* (a short, wide-ish matrix), so it only ever sees `d × n`
//! inputs with `d = O(n)` — the O(mn²) cost lives here, not on the tall
//! input. `qr_thin` is the deterministic baseline the decomposition benches
//! compare against.

use super::Mat;

/// Thin QR via Householder reflections: `A = Q·R` with `Q: m×n` (orthonormal
/// columns, requires m ≥ n) and `R: n×n` upper-triangular.
pub fn qr_thin(a: &Mat) -> (Mat, Mat) {
    let (m, n) = a.shape();
    assert!(m >= n, "qr_thin requires m >= n (got {m}x{n})");
    // Work in f64 for stability; matrices here are modest (n ≤ few hundred).
    let mut r = to_f64(a);
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n); // Householder vectors
    for k in 0..n {
        // Build the reflector for column k below the diagonal.
        let mut x = vec![0f64; m - k];
        for i in k..m {
            x[i - k] = r[i * n + k];
        }
        let normx = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        if normx < 1e-300 {
            vs.push(vec![0f64; m - k]);
            continue;
        }
        let alpha = if x[0] >= 0.0 { -normx } else { normx };
        let mut v = x;
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|t| t * t).sum::<f64>();
        if vnorm2 < 1e-300 {
            vs.push(vec![0f64; m - k]);
            continue;
        }
        // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..].
        for j in k..n {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i - k] * r[i * n + j];
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                r[i * n + j] -= c * v[i - k];
            }
        }
        vs.push(v);
    }
    // Extract R (upper n×n).
    let mut rmat = Mat::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            rmat.set(i, j, r[i * n + j] as f32);
        }
    }
    // Form thin Q by applying reflectors to the first n columns of I.
    let mut q = vec![0f64; m * n];
    for j in 0..n {
        q[j * n + j] = 1.0;
    }
    for k in (0..n).rev() {
        let v = &vs[k];
        if v.is_empty() || v.iter().all(|&t| t == 0.0) {
            continue;
        }
        let vnorm2 = v.iter().map(|t| t * t).sum::<f64>();
        for j in 0..n {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i - k] * q[i * n + j];
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * n + j] -= c * v[i - k];
            }
        }
    }
    let qmat = Mat::from_vec(m, n, q.into_iter().map(|v| v as f32).collect());
    (qmat, rmat)
}

/// Result of column-pivoted QR: `A·P = Q·R`, `perm[j]` = original index of
/// the j-th pivoted column, `rank` = numerical rank at tolerance `tol`.
pub struct QrCp {
    pub q: Mat,
    pub r: Mat,
    pub perm: Vec<usize>,
    pub rank: usize,
}

/// Column-pivoted Householder QR (LAPACK `geqp3`-style greedy pivoting on
/// remaining column norms).
pub fn qr_cp(a: &Mat, tol: f64) -> QrCp {
    let (m, n) = a.shape();
    let kmax = m.min(n);
    let mut work = to_f64(a);
    let mut perm: Vec<usize> = (0..n).collect();
    // Column norms (squared), updated as we go; recomputed when cancellation
    // makes the running value unreliable.
    let mut cnorm2: Vec<f64> = (0..n)
        .map(|j| (0..m).map(|i| work[i * n + j].powi(2)).sum())
        .collect();
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(kmax);
    let mut rank = kmax;
    let norm_tol = {
        let max0 = cnorm2.iter().cloned().fold(0f64, f64::max).sqrt();
        (tol * max0.max(1e-300)).powi(2)
    };
    for k in 0..kmax {
        // Pivot: remaining column with the largest norm.
        let (jmax, &nmax) = cnorm2[k..]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(off, v)| (k + off, v))
            .unwrap();
        if nmax <= norm_tol {
            rank = k;
            // Zero vectors for remaining reflectors (identity).
            for _ in k..kmax {
                vs.push(Vec::new());
            }
            break;
        }
        if jmax != k {
            for i in 0..m {
                work.swap(i * n + k, i * n + jmax);
            }
            perm.swap(k, jmax);
            cnorm2.swap(k, jmax);
        }
        // Householder on column k.
        let mut x = vec![0f64; m - k];
        for i in k..m {
            x[i - k] = work[i * n + k];
        }
        let normx = x.iter().map(|v| v * v).sum::<f64>().sqrt();
        let alpha = if x[0] >= 0.0 { -normx } else { normx };
        let mut v = x;
        v[0] -= alpha;
        let vnorm2 = v.iter().map(|t| t * t).sum::<f64>();
        if vnorm2 < 1e-300 {
            vs.push(Vec::new());
            continue;
        }
        for j in k..n {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i - k] * work[i * n + j];
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                work[i * n + j] -= c * v[i - k];
            }
        }
        vs.push(v);
        // Downdate column norms for columns right of k.
        for j in (k + 1)..n {
            let rkj = work[k * n + j];
            cnorm2[j] -= rkj * rkj;
            if cnorm2[j] < 1e-12 * norm_tol.max(1e-300) || cnorm2[j] < 0.0 {
                // Recompute to dodge cancellation.
                cnorm2[j] = ((k + 1)..m).map(|i| work[i * n + j].powi(2)).sum();
            }
        }
    }
    // R: kmax×n upper-trapezoidal.
    let mut rmat = Mat::zeros(kmax, n);
    for i in 0..kmax {
        for j in i..n {
            rmat.set(i, j, work[i * n + j] as f32);
        }
    }
    // Thin Q: m×kmax.
    let mut q = vec![0f64; m * kmax];
    for j in 0..kmax {
        q[j * kmax + j] = 1.0;
    }
    for k in (0..kmax).rev() {
        let v = match vs.get(k) {
            Some(v) if !v.is_empty() => v,
            _ => continue,
        };
        let vnorm2 = v.iter().map(|t| t * t).sum::<f64>();
        for j in 0..kmax {
            let mut dot = 0f64;
            for i in k..m {
                dot += v[i - k] * q[i * kmax + j];
            }
            let c = 2.0 * dot / vnorm2;
            for i in k..m {
                q[i * kmax + j] -= c * v[i - k];
            }
        }
    }
    QrCp {
        q: Mat::from_vec(m, kmax, q.into_iter().map(|v| v as f32).collect()),
        r: rmat,
        perm,
        rank,
    }
}

fn to_f64(a: &Mat) -> Vec<f64> {
    a.data().iter().map(|&x| x as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, matmul, ortho_error, rel_error};
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    #[test]
    fn thin_qr_reconstructs() {
        let mut rng = Philox::seeded(21);
        let a = Mat::randn(50, 20, &mut rng);
        let (q, r) = qr_thin(&a);
        assert_eq!(q.shape(), (50, 20));
        assert_eq!(r.shape(), (20, 20));
        assert!(rel_error(&matmul(&q, &r), &a) < 1e-5);
        assert!(ortho_error(&q) < 1e-5);
    }

    #[test]
    fn r_is_upper_triangular() {
        let mut rng = Philox::seeded(22);
        let a = Mat::randn(30, 10, &mut rng);
        let (_q, r) = qr_thin(&a);
        for i in 0..10 {
            for j in 0..i {
                assert_eq!(r.get(i, j), 0.0);
            }
        }
    }

    #[test]
    fn qr_property_orthogonal_and_exact() {
        prop_check("qr-thin-props", 25, |g| {
            let n = g.usize(1..12);
            let m = n + g.usize(0..20);
            let a = Mat::randn(m, n, g.rng());
            let (q, r) = qr_thin(&a);
            assert!(ortho_error(&q) < 1e-4, "ortho {}", ortho_error(&q));
            assert!(rel_error(&matmul(&q, &r), &a) < 1e-4);
        });
    }

    #[test]
    fn pivoted_qr_reconstructs_with_permutation() {
        let mut rng = Philox::seeded(23);
        let a = Mat::randn(40, 15, &mut rng);
        let f = qr_cp(&a, 1e-10);
        let ap = a.permute_cols(&f.perm);
        assert!(rel_error(&matmul(&f.q, &f.r), &ap) < 1e-4);
        assert!(ortho_error(&f.q) < 1e-4);
        assert_eq!(f.rank, 15);
    }

    #[test]
    fn pivoted_qr_detects_rank() {
        // Rank-3 matrix: outer product structure.
        let mut rng = Philox::seeded(24);
        let u = Mat::randn(30, 3, &mut rng);
        let v = Mat::randn(3, 12, &mut rng);
        let a = matmul(&u, &v);
        let f = qr_cp(&a, 1e-5);
        assert_eq!(f.rank, 3, "expected rank 3");
    }

    #[test]
    fn pivoted_diagonal_decreasing() {
        // |R[k,k]| must be non-increasing under greedy pivoting.
        let mut rng = Philox::seeded(25);
        let a = Mat::randn(25, 10, &mut rng);
        let f = qr_cp(&a, 1e-12);
        for k in 1..10 {
            let prev = f.r.get(k - 1, k - 1).abs();
            let cur = f.r.get(k, k).abs();
            assert!(
                cur <= prev * 1.3 + 1e-4,
                "diagonal grew at {k}: {cur} > {prev}"
            );
        }
    }

    #[test]
    fn zero_matrix_rank_zero() {
        let a = Mat::zeros(10, 4);
        let f = qr_cp(&a, 1e-10);
        assert_eq!(f.rank, 0);
        assert!(fro_norm(&f.r) < 1e-6);
    }
}
