//! Triangular solves and inversion, used by CholeskyQR / CQRRPT
//! (preconditioning `A · R⁻¹`) and by RSVD's re-orthonormalization.

use super::Mat;

/// Solve `R · X = B` for X, with `R` upper-triangular (n×n), `B` n×m.
pub fn solve_triu(r: &Mat, b: &Mat) -> Mat {
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(b.rows(), n);
    let m = b.cols();
    let mut x = vec![0f64; n * m];
    for j in 0..m {
        for i in (0..n).rev() {
            let mut s = b.get(i, j) as f64;
            for p in (i + 1)..n {
                s -= r.get(i, p) as f64 * x[p * m + j];
            }
            let d = r.get(i, i) as f64;
            x[i * m + j] = s / d;
        }
    }
    Mat::from_vec(n, m, x.into_iter().map(|v| v as f32).collect())
}

/// Solve `X · R = B` for X, with `R` upper-triangular (n×n), `B` m×n.
/// This is the CholeskyQR preconditioning step `A_pre = A · R⁻¹`.
pub fn solve_triu_right(b: &Mat, r: &Mat) -> Mat {
    let n = r.rows();
    assert_eq!(r.cols(), n);
    assert_eq!(b.cols(), n);
    let m = b.rows();
    let mut x = Mat::zeros(m, n);
    for i in 0..m {
        let brow = b.row(i);
        // Forward sweep over columns: x[i,j] = (b[i,j] - Σ_{p<j} x[i,p] R[p,j]) / R[j,j]
        let mut xrow = vec![0f64; n];
        for j in 0..n {
            let mut s = brow[j] as f64;
            for (p, xv) in xrow.iter().enumerate().take(j) {
                s -= xv * r.get(p, j) as f64;
            }
            xrow[j] = s / r.get(j, j) as f64;
        }
        for (j, v) in xrow.into_iter().enumerate() {
            x.set(i, j, v as f32);
        }
    }
    x
}

/// Invert an upper-triangular matrix.
pub fn inv_triu(r: &Mat) -> Mat {
    solve_triu(r, &Mat::eye(r.rows()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, rel_error};
    use crate::rng::{Philox, Rng};

    /// Random well-conditioned upper-triangular matrix.
    fn rand_triu(n: usize, rng: &mut Philox) -> Mat {
        let mut r = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                r.set(i, j, rng.next_normal() * 0.3);
            }
            // Push the diagonal away from zero.
            r.set(i, i, 1.0 + rng.next_f32());
        }
        r
    }

    #[test]
    fn solve_left() {
        let mut rng = Philox::seeded(41);
        let r = rand_triu(8, &mut rng);
        let x_true = Mat::randn(8, 5, &mut rng);
        let b = matmul(&r, &x_true);
        let x = solve_triu(&r, &b);
        assert!(rel_error(&x, &x_true) < 1e-4);
    }

    #[test]
    fn solve_right() {
        let mut rng = Philox::seeded(42);
        let r = rand_triu(8, &mut rng);
        let x_true = Mat::randn(6, 8, &mut rng);
        let b = matmul(&x_true, &r);
        let x = solve_triu_right(&b, &r);
        assert!(rel_error(&x, &x_true) < 1e-4);
    }

    #[test]
    fn inverse_roundtrip() {
        let mut rng = Philox::seeded(43);
        let r = rand_triu(10, &mut rng);
        let rinv = inv_triu(&r);
        let prod = matmul(&r, &rinv);
        assert!(rel_error(&prod, &Mat::eye(10)) < 1e-4);
    }

    #[test]
    fn identity_solve_is_copy() {
        let mut rng = Philox::seeded(44);
        let b = Mat::randn(4, 4, &mut rng);
        assert!(rel_error(&solve_triu(&Mat::eye(4), &b), &b) < 1e-7);
        assert!(rel_error(&solve_triu_right(&b, &Mat::eye(4)), &b) < 1e-7);
    }
}
