//! Dense linear-algebra substrate.
//!
//! The paper's backend (pawX) bundles OpenBLAS; nothing of the sort is
//! available here, so this module implements the dense kernels the rest of
//! the crate needs: a row-major [`Mat`] type, blocked multi-threaded GEMM,
//! Householder QR (plain and column-pivoted), Cholesky, triangular solves,
//! and a one-sided Jacobi SVD. Everything is f32 storage with f64
//! accumulation in reductions, which keeps the decompositions stable enough
//! for the CQRRPT/RSVD experiments.

mod chol;
mod gemm;
mod mat;
mod qr;
mod svd;
mod tri;

pub use chol::{cholesky_lower, CholError};
pub use gemm::{
    gemm, gemm_batch, gemm_threads, install_profiler, matmul, matmul_nt, matmul_tn,
    set_gemm_threads, GemmPoolError, GemmProfilerGuard,
};
pub use mat::{Mat, MatMut, MatRef};
pub use qr::{qr_cp, qr_thin, QrCp};
pub use svd::{svd_jacobi, Svd};
pub use tri::{solve_triu, solve_triu_right, inv_triu};

/// Frobenius norm.
pub fn fro_norm(a: &Mat) -> f64 {
    a.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// Relative Frobenius error ‖a − b‖_F / ‖b‖_F.
pub fn rel_error(a: &Mat, b: &Mat) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let mut num = 0f64;
    let mut den = 0f64;
    for (&x, &y) in a.data().iter().zip(b.data()) {
        num += ((x - y) as f64).powi(2);
        den += (y as f64).powi(2);
    }
    (num / den.max(1e-300)).sqrt()
}

/// Orthogonality defect ‖QᵀQ − I‖_F — the metric the CQRRPT paper reports.
pub fn ortho_error(q: &Mat) -> f64 {
    let qtq = matmul_tn(q, q);
    let n = qtq.rows();
    let mut err = 0f64;
    for i in 0..n {
        for j in 0..n {
            let target = if i == j { 1.0 } else { 0.0 };
            err += ((qtq.get(i, j) as f64) - target).powi(2);
        }
    }
    err.sqrt()
}

/// Largest singular value estimate via power iteration on AᵀA.
pub fn spectral_norm_est(a: &Mat, iters: usize, seed: u64) -> f64 {
    use crate::rng::{fill_normal, Philox};
    let n = a.cols();
    if n == 0 || a.rows() == 0 {
        return 0.0;
    }
    let mut rng = Philox::seeded(seed);
    let mut v = vec![0f32; n];
    fill_normal(&mut rng, &mut v);
    normalize(&mut v);
    let mut est = 0f64;
    for _ in 0..iters {
        // w = A v ; v' = Aᵀ w
        let w = a.matvec(&v);
        let v2 = a.matvec_t(&w);
        est = norm2(&v2).sqrt(); // ‖AᵀAv‖ ≈ σ² when v is the top vector
        v = v2;
        let nv = norm2(&v).sqrt();
        if nv < 1e-30 {
            return 0.0;
        }
        for x in &mut v {
            *x = (*x as f64 / nv) as f32;
        }
    }
    est.sqrt()
}

fn norm2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum()
}

fn normalize(v: &mut [f32]) {
    let n = norm2(v).sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x = (*x as f64 / n) as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn fro_norm_identity() {
        let i = Mat::eye(4);
        assert!((fro_norm(&i) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn rel_error_zero_for_equal() {
        let mut rng = Philox::seeded(1);
        let a = Mat::randn(5, 7, &mut rng);
        assert_eq!(rel_error(&a, &a), 0.0);
    }

    #[test]
    fn ortho_error_of_identity_is_zero() {
        let q = Mat::eye(6);
        assert!(ortho_error(&q) < 1e-7);
    }

    #[test]
    fn spectral_norm_diag() {
        // diag(3, 1) has spectral norm 3.
        let mut a = Mat::zeros(2, 2);
        a.set(0, 0, 3.0);
        a.set(1, 1, 1.0);
        let s = spectral_norm_est(&a, 50, 7);
        assert!((s - 3.0).abs() < 1e-3, "{s}");
    }
}
