//! Synthetic CIFAR-like image dataset.

use crate::rng::{Philox, Rng};
use crate::runtime::HostTensor;

/// Class-conditional image generator: class `c` determines a grating
/// orientation/frequency and a quadrant blob color; additive Gaussian noise
/// keeps Bayes accuracy below 100% so dense-vs-sketched accuracy deltas are
/// visible (the §4.2 case study reports 89% vs 86%).
pub struct ImageDataset {
    pub classes: usize,
    pub channels: usize,
    pub image: usize,
    noise: f32,
}

impl ImageDataset {
    pub fn new(classes: usize, channels: usize, image: usize, noise: f32) -> Self {
        assert!(classes >= 2 && channels >= 1 && image >= 4);
        ImageDataset {
            classes,
            channels,
            image,
            noise,
        }
    }

    /// CIFAR-ish defaults matching the conv artifacts (3×16×16, 10 classes).
    /// Noise is calibrated so a small CNN lands in the high-80s/low-90s —
    /// the regime of the paper's §4.2 case study (89% dense), where model
    /// capacity matters and the dense-vs-sketched gap is visible.
    pub fn cifar_like() -> Self {
        Self::new(10, 3, 16, 1.1)
    }

    /// Render one image of class `c` into `out` (C·H·W layout).
    fn render(&self, c: usize, rng: &mut Philox, out: &mut [f32]) {
        let h = self.image;
        let freq = 1.0 + (c % 5) as f32;
        let theta = (c as f32) * std::f32::consts::PI / self.classes as f32;
        let (st, ct) = theta.sin_cos();
        let phase = rng.next_f32() * std::f32::consts::TAU;
        // Blob quadrant from the class' upper bits.
        let (qy, qx) = ((c / 5) % 2, c % 2);
        for ch in 0..self.channels {
            for y in 0..h {
                for x in 0..h {
                    let fy = y as f32 / h as f32 - 0.5;
                    let fx = x as f32 / h as f32 - 0.5;
                    // Oriented grating (same for all channels).
                    let wave =
                        (freq * std::f32::consts::TAU * (fx * ct + fy * st) + phase).sin() * 0.5;
                    // Class blob: channel-selective bump in a quadrant.
                    let by = qy as f32 * 0.5 - 0.25;
                    let bx = qx as f32 * 0.5 - 0.25;
                    let d2 = (fy - by).powi(2) + (fx - bx).powi(2);
                    let blob = if ch == c % self.channels {
                        0.8 * (-d2 * 40.0).exp()
                    } else {
                        0.0
                    };
                    out[ch * h * h + y * h + x] =
                        wave + blob + self.noise * rng.next_normal();
                }
            }
        }
    }

    /// Sample a batch: images `(B, C·H·W)` and labels `(B,)` (f32 ids).
    pub fn batch(&self, batch: usize, rng: &mut Philox) -> (HostTensor, HostTensor) {
        let px = self.channels * self.image * self.image;
        let mut images = vec![0f32; batch * px];
        let mut labels = vec![0f32; batch];
        for b in 0..batch {
            let c = rng.next_below(self.classes as u32) as usize;
            labels[b] = c as f32;
            self.render(c, rng, &mut images[b * px..(b + 1) * px]);
        }
        (
            HostTensor::new(&[batch, px], images),
            HostTensor::new(&[batch], labels),
        )
    }

    /// Accuracy of predictions (argmax over logits rows) vs labels.
    pub fn accuracy(logits: &HostTensor, labels: &HostTensor) -> f64 {
        let (b, c) = (logits.shape()[0], logits.shape()[1]);
        assert_eq!(labels.shape(), &[b]);
        let mut correct = 0usize;
        for i in 0..b {
            let row = &logits.data()[i * c..(i + 1) * c];
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == labels.data()[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes() {
        let ds = ImageDataset::cifar_like();
        let mut rng = Philox::seeded(1);
        let (x, y) = ds.batch(8, &mut rng);
        assert_eq!(x.shape(), &[8, 3 * 16 * 16]);
        assert_eq!(y.shape(), &[8]);
        assert!(y.data().iter().all(|&l| l < 10.0));
    }

    #[test]
    fn classes_are_distinguishable() {
        // Mean pixel distance between two classes should exceed within-class
        // distance — crude separability check.
        let ds = ImageDataset::new(10, 3, 16, 0.1);
        let mut rng = Philox::seeded(2);
        let px = 3 * 16 * 16;
        let mut img = |c: usize, r: &mut Philox| {
            let mut buf = vec![0f32; px];
            ds.render(c, r, &mut buf);
            buf
        };
        let a1 = img(0, &mut rng);
        let a2 = img(0, &mut rng);
        let b1 = img(7, &mut rng);
        let dist = |x: &[f32], y: &[f32]| -> f64 {
            x.iter()
                .zip(y)
                .map(|(&u, &v)| ((u - v) as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        // Within-class images differ only by phase+noise; cross-class
        // differ by blob position and frequency as well.
        assert!(dist(&a1, &b1) > 0.6 * dist(&a1, &a2), "classes indistinct");
    }

    #[test]
    fn accuracy_metric() {
        let logits = HostTensor::new(&[2, 3], vec![0.1, 0.9, 0.0, 0.8, 0.1, 0.1]);
        let labels = HostTensor::new(&[2], vec![1.0, 2.0]);
        assert_eq!(ImageDataset::accuracy(&logits, &labels), 0.5);
    }
}
