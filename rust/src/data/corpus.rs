//! Synthetic character corpus + MLM batch generation.

use crate::rng::{Philox, Rng};
use crate::runtime::HostTensor;

/// MLM masking: select 15% of positions; replace 80% of those with
/// `[MASK]`, leave 20% unchanged.
///
/// Deviation from BERT's full 80/10/10 recipe (10% random-token
/// substitution dropped): at this model scale (~0.5M params, a few hundred
/// steps) the random-substitution noise measurably prevents the model from
/// ever learning the corpus' Markov structure — an A/B on identical data
/// shows 4.6 vs 5.3 nats at step 300 (see EXPERIMENTS.md §4.2 notes). The
/// dense-vs-sketched comparison is unaffected: both variants see the same
/// recipe.
const MASK_FRAC: f64 = 0.15;

/// Special token ids (kept below `vocab`): 0 = PAD (unused), 1 = MASK.
pub const MASK_TOKEN: u32 = 1;
const FIRST_REAL_TOKEN: u32 = 2;

/// A Markov-chain text corpus over `vocab` tokens. Transition rows are
/// Zipf-weighted permutations, giving per-token conditional entropy far
/// below `ln(vocab)` — an MLM model that learns the chain beats the
/// unigram baseline by a wide, measurable margin.
pub struct TextCorpus {
    vocab: usize,
    tokens: Vec<u32>,
}

impl TextCorpus {
    /// Generate `len` tokens over a `vocab`-sized alphabet.
    pub fn generate(vocab: usize, len: usize, seed: u64) -> Self {
        assert!(vocab >= 8, "vocab too small");
        let mut rng = Philox::seeded(seed);
        let real = vocab as u32 - FIRST_REAL_TOKEN;
        // Per-state successor tables: each state prefers a few successors
        // with Zipf weights. Fixed fan-out keeps generation O(1).
        const FANOUT: usize = 8;
        let succ: Vec<[u32; FANOUT]> = (0..real)
            .map(|_| {
                let mut row = [0u32; FANOUT];
                for r in row.iter_mut() {
                    *r = FIRST_REAL_TOKEN + rng.next_below(real);
                }
                row
            })
            .collect();
        // Zipf CDF over fan-out ranks: w_r ∝ 1/(r+1).
        let weights: Vec<f64> = (0..FANOUT).map(|r| 1.0 / (r + 1) as f64).collect();
        let total: f64 = weights.iter().sum();
        let cdf: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w / total;
                Some(*acc)
            })
            .collect();
        let mut tokens = Vec::with_capacity(len);
        let mut state = FIRST_REAL_TOKEN;
        for _ in 0..len {
            let u = rng.next_f64();
            let rank = cdf.iter().position(|&c| u <= c).unwrap_or(FANOUT - 1);
            state = succ[(state - FIRST_REAL_TOKEN) as usize][rank];
            tokens.push(state);
        }
        TextCorpus { vocab, tokens }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn tokens(&self) -> &[u32] {
        &self.tokens
    }

    /// Sample one MLM batch: `batch` windows of `seq` tokens, masked.
    pub fn mlm_batch(&self, batch: usize, seq: usize, rng: &mut Philox) -> MaskedBatch {
        assert!(self.tokens.len() > seq + 1, "corpus shorter than seq");
        let mut tokens = vec![0f32; batch * seq];
        let mut labels = vec![0f32; batch * seq];
        let mut mask = vec![0f32; batch * seq];
        for b in 0..batch {
            let start = rng.next_below((self.tokens.len() - seq) as u32) as usize;
            for s in 0..seq {
                let orig = self.tokens[start + s];
                labels[b * seq + s] = orig as f32;
                let masked = rng.next_f64() < MASK_FRAC;
                let visible = if masked {
                    mask[b * seq + s] = 1.0;
                    let u = rng.next_f64();
                    if u < 0.8 {
                        MASK_TOKEN
                    } else {
                        orig // 20% unchanged (see MASK_FRAC docs)
                    }
                } else {
                    orig
                };
                tokens[b * seq + s] = visible as f32;
            }
        }
        MaskedBatch {
            tokens: HostTensor::new(&[batch, seq], tokens),
            labels: HostTensor::new(&[batch, seq], labels),
            mask: HostTensor::new(&[batch, seq], mask),
        }
    }

    /// Empirical unigram entropy (nats) — a sanity baseline for MLM loss.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }
}

/// One MLM batch in artifact layout (all f32; see model.py docs).
pub struct MaskedBatch {
    pub tokens: HostTensor,
    pub labels: HostTensor,
    pub mask: HostTensor,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_tokens_in_range() {
        let c = TextCorpus::generate(64, 10_000, 1);
        assert_eq!(c.len(), 10_000);
        assert!(c.tokens().iter().all(|&t| (t as usize) < 64));
        assert!(c.tokens().iter().all(|&t| t >= FIRST_REAL_TOKEN));
    }

    #[test]
    fn corpus_has_structure() {
        // Markov chain entropy must be far below uniform ln(62) ≈ 4.13…
        // unigram entropy alone is lower too since states are visited
        // non-uniformly through Zipf transitions.
        let c = TextCorpus::generate(64, 50_000, 2);
        let h = c.unigram_entropy();
        assert!(h < 4.2, "unigram entropy {h}");
        assert!(h > 1.0, "degenerate corpus {h}");
    }

    #[test]
    fn batch_shapes_and_mask_stats() {
        let c = TextCorpus::generate(64, 10_000, 3);
        let mut rng = Philox::seeded(9);
        let b = c.mlm_batch(8, 32, &mut rng);
        assert_eq!(b.tokens.shape(), &[8, 32]);
        assert_eq!(b.labels.shape(), &[8, 32]);
        assert_eq!(b.mask.shape(), &[8, 32]);
        let frac = b.mask.data().iter().sum::<f32>() / 256.0;
        assert!((0.05..0.30).contains(&frac), "mask fraction {frac}");
        // Labels hold the original tokens; masked positions may differ in
        // the visible stream.
        for (i, &m) in b.mask.data().iter().enumerate() {
            if m == 0.0 {
                assert_eq!(b.tokens.data()[i], b.labels.data()[i]);
            }
        }
    }

    #[test]
    fn deterministic_given_seeds() {
        let c1 = TextCorpus::generate(32, 1000, 7);
        let c2 = TextCorpus::generate(32, 1000, 7);
        assert_eq!(c1.tokens(), c2.tokens());
    }
}
