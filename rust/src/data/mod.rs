//! Synthetic datasets (WikiText and CIFAR-10 stand-ins — no network access
//! in this environment; see DESIGN.md §Substitutions).
//!
//! - [`corpus`]: a Markov-chain character corpus with Zipf-distributed
//!   state transitions. It has real sequential structure (entropy well
//!   below uniform), so MLM training shows a genuine learning curve and
//!   dense-vs-sketched loss comparisons are meaningful.
//! - [`images`]: class-conditional structured images (oriented gratings +
//!   class-dependent quadrant blobs, plus noise) for the CIFAR case study —
//!   not linearly separable, but learnable by a small CNN.

pub mod corpus;
pub mod images;

pub use corpus::{MaskedBatch, TextCorpus};
pub use images::ImageDataset;
