//! The execution-backend seam: everything above the runtime (coordinator,
//! trainers, tuner, benches) talks to artifacts through [`ExecBackend`], so
//! the concrete executor is swappable:
//!
//! - [`ReferenceBackend`] (default) — interprets the manifest's builtin
//!   graphs on the in-crate `linalg` substrate; works fully offline.
//! - `PjrtBackend` (cargo feature `pjrt`) — compiles the AOT HLO text via
//!   the `xla` crate's PJRT CPU client, exactly what production runs.
//!
//! Backends receive inputs that [`super::Runtime`] has already arity- and
//! shape-checked against the manifest.

use super::manifest::ArtifactSpec;
use super::tensor::HostTensor;
use anyhow::Result;
use std::path::Path;

/// An artifact executor. Implementations may be `!Send` (the PJRT client
/// wraps raw C pointers), which is why the coordinator confines the whole
/// [`super::Runtime`] to one service thread.
pub trait ExecBackend {
    /// Human-readable backend name (logs, `panther info`).
    fn name(&self) -> &'static str;

    /// Prepare an artifact for execution (compile + cache). Called once per
    /// artifact before the first `execute`; must be idempotent.
    fn load(&mut self, spec: &ArtifactSpec, dir: &Path) -> Result<()>;

    /// Execute a loaded artifact on shape-checked inputs.
    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// Executes the manifest's builtin graphs on the in-crate substrate.
#[derive(Default)]
pub struct ReferenceBackend;

impl ReferenceBackend {
    pub fn new() -> Self {
        ReferenceBackend
    }
}

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn load(&mut self, spec: &ArtifactSpec, _dir: &Path) -> Result<()> {
        // The reference analogue of a compile failure: reject artifacts
        // whose `ref` config names no (or an unknown) builtin graph.
        super::reference::check(spec)
    }

    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        super::reference::execute(spec, inputs)
    }
}
