//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! `make artifacts` (the only step that runs Python) leaves
//! `artifacts/*.hlo.txt` plus `manifest.json`; everything here is pure Rust
//! on top of the `xla` crate's PJRT CPU client:
//!
//! - [`tensor::HostTensor`] — host-side f32 tensor exchanged with HLO
//!   executables (row-major, matching [`crate::linalg::Mat`]).
//! - [`manifest::Manifest`] — parsed `manifest.json`: artifact input/output
//!   specs, model descriptors (param names/order, config).
//! - [`Runtime`] — compile-on-demand executable cache + name-checked
//!   execution.
//!
//! The PJRT client wrapper is not `Send` (raw C pointers), so a `Runtime`
//! lives on one thread; [`crate::coordinator`] owns one on a dedicated
//! service thread and multiplexes requests over channels.

pub mod manifest;
pub mod tensor;

pub use manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec};
pub use tensor::HostTensor;

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Loaded runtime: PJRT client + manifest + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions per artifact (metrics).
    exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            dir,
            cache: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Default artifact location: `$PANTHER_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("PANTHER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile an artifact (cached after the first call).
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact {name} not in manifest"))?;
        let path = self.dir.join(&spec.path);
        let t0 = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        crate::log_info!(
            "compiled artifact {name} in {}",
            crate::util::human_duration(t0.elapsed())
        );
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with shape-checked inputs; returns the flattened
    /// output tensors (the HLO returns one tuple; we decompose it).
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?;
        let spec = self.manifest.artifact(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "artifact {name} input {i} ({}): shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let exe = self.cache.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(&literals)?;
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        // return_tuple=True → single tuple output on replica 0.
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: manifest declares {} outputs, HLO returned {}",
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, os)| HostTensor::from_literal(lit, &os.shape))
            .collect()
    }

    /// Total executions of an artifact so far.
    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.get(name).copied().unwrap_or(0)
    }

    /// Number of compiled executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn kernel_artifact_roundtrip() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let spec = rt.manifest().artifact("k_sk_linear").unwrap().clone();
        // Zero inputs → output should equal the (zero) bias broadcast.
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        let out = rt.execute("k_sk_linear", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].data().iter().all(|&v| v == 0.0));
        assert_eq!(rt.exec_count("k_sk_linear"), 1);
        assert_eq!(rt.cached_executables(), 1);
    }

    #[test]
    fn execute_rejects_bad_arity_and_shape() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt
            .execute("k_sk_linear", &[HostTensor::zeros(&[1])])
            .is_err());
        let spec = rt.manifest().artifact("k_sk_linear").unwrap().clone();
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        inputs[0] = HostTensor::zeros(&[3, 3]);
        assert!(rt.execute("k_sk_linear", &inputs).is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt.execute("no_such_artifact", &[]).is_err());
    }
}
