//! Artifact runtime: load the manifest and execute model/kernel graphs.
//!
//! The artifact directory holds `manifest.json` plus (for PJRT builds) the
//! `*.hlo.txt` files `make artifacts` lowered from the JAX layer. Execution
//! goes through the [`ExecBackend`] seam:
//!
//! - **default build** — [`ReferenceBackend`] interprets each artifact's
//!   builtin graph (named by its `"ref"` manifest entry) directly on the
//!   in-crate [`crate::linalg`]/[`crate::nn`] substrate. Fully offline; the
//!   committed `artifacts/manifest.json` works out of the box.
//! - **`--features pjrt`** — the `xla` crate's PJRT CPU client compiles and
//!   runs the real HLO. Set `PANTHER_BACKEND=reference` to force the
//!   reference backend even in a pjrt build.
//!
//! Components:
//! - [`tensor::HostTensor`] — host-side f32 tensors exchanged with
//!   executables (row-major, matching [`crate::linalg::Mat`]).
//! - [`manifest::Manifest`] — parsed `manifest.json`: artifact input/output
//!   specs, model descriptors (param names/order, config).
//! - [`Runtime`] — compile-on-demand executable cache + name-checked
//!   execution on top of a backend.
//!
//! A backend may be `!Send` (the PJRT client wraps raw C pointers), so a
//! `Runtime` lives on one thread; [`crate::coordinator`] owns one on a
//! dedicated service thread and multiplexes requests over channels.

pub mod backend;
pub mod manifest;
mod reference;
pub mod tensor;

#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{ExecBackend, ReferenceBackend};
pub use manifest::{ArtifactSpec, Manifest, ModelSpec, TensorSpec};
pub use tensor::HostTensor;

use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};

/// Loaded runtime: execution backend + manifest + loaded-artifact cache.
pub struct Runtime {
    backend: Box<dyn ExecBackend>,
    manifest: Manifest,
    dir: PathBuf,
    loaded: HashSet<String>,
    /// Executions per artifact (metrics).
    exec_counts: HashMap<String, u64>,
}

impl Runtime {
    /// Open the artifact directory (expects `manifest.json` inside) with the
    /// build's default backend.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        Self::open_with(dir, default_backend()?)
    }

    /// Open with an explicit backend (tests, embedding).
    pub fn open_with(dir: impl AsRef<Path>, backend: Box<dyn ExecBackend>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).with_context(|| {
            format!(
                "reading {manifest_path:?} — point at the committed reference \
                 artifacts (rust/artifacts) or run `make artifacts`"
            )
        })?;
        let manifest = Manifest::parse(&text)?;
        Ok(Runtime {
            backend,
            manifest,
            dir,
            loaded: HashSet::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Default artifact location: `$PANTHER_ARTIFACTS` or `./artifacts`.
    pub fn open_default() -> Result<Self> {
        let dir = std::env::var("PANTHER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::open(dir)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Name of the active execution backend (`"reference"` or `"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Prepare an artifact (compile on PJRT, validate on reference); cached
    /// after the first call.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.loaded.contains(name) {
            return Ok(());
        }
        let spec = self
            .manifest
            .artifact(name)
            .with_context(|| format!("artifact {name} not in manifest"))?
            .clone();
        let t0 = std::time::Instant::now();
        self.backend.load(&spec, &self.dir)?;
        crate::log_info!(
            "loaded artifact {name} on {} backend in {}",
            self.backend.name(),
            crate::util::human_duration(t0.elapsed())
        );
        self.loaded.insert(name.to_string());
        Ok(())
    }

    /// Execute an artifact with shape-checked inputs; returns the flattened
    /// output tensors.
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?;
        let spec = self.manifest.artifact(name).unwrap().clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "artifact {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "artifact {name} input {i} ({}): shape {:?} != manifest {:?}",
                    s.name,
                    t.shape(),
                    s.shape
                );
            }
        }
        let out = self.backend.execute(&spec, inputs)?;
        // Backend-agnostic output validation: a manifest whose declared
        // outputs drift from what the executor produces should fail here,
        // not as a confusing index/shape error downstream.
        if out.len() != spec.outputs.len() {
            bail!(
                "artifact {name}: manifest declares {} outputs, backend returned {}",
                spec.outputs.len(),
                out.len()
            );
        }
        for (i, (t, s)) in out.iter().zip(&spec.outputs).enumerate() {
            if t.shape() != s.shape.as_slice() {
                bail!(
                    "artifact {name} output {i}: shape {:?} != manifest {:?}",
                    t.shape(),
                    s.shape
                );
            }
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        Ok(out)
    }

    /// Total executions of an artifact so far.
    pub fn exec_count(&self, name: &str) -> u64 {
        self.exec_counts.get(name).copied().unwrap_or(0)
    }

    /// Number of artifacts currently loaded (compiled/validated + cached).
    pub fn cached_executables(&self) -> usize {
        self.loaded.len()
    }
}

/// The backend this build executes with. `PANTHER_BACKEND` selects
/// explicitly (`reference` or `pjrt`; anything else is an error, and `pjrt`
/// errors on builds without the feature); unset, a `pjrt` build uses the
/// PJRT client and a default build uses the reference backend.
fn default_backend() -> Result<Box<dyn ExecBackend>> {
    match std::env::var("PANTHER_BACKEND").ok().as_deref() {
        Some("reference") => reference_backend(),
        Some("pjrt") => pjrt_backend(),
        Some(other) => bail!(
            "unknown PANTHER_BACKEND '{other}' (expected 'reference' or 'pjrt')"
        ),
        None => build_default_backend(),
    }
}

fn reference_backend() -> Result<Box<dyn ExecBackend>> {
    Ok(Box::new(ReferenceBackend::new()))
}

#[cfg(feature = "pjrt")]
fn pjrt_backend() -> Result<Box<dyn ExecBackend>> {
    Ok(Box::new(pjrt::PjrtBackend::new()?))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend() -> Result<Box<dyn ExecBackend>> {
    bail!("PANTHER_BACKEND=pjrt requires a build with --features pjrt")
}

#[cfg(feature = "pjrt")]
fn build_default_backend() -> Result<Box<dyn ExecBackend>> {
    pjrt_backend()
}

#[cfg(not(feature = "pjrt"))]
fn build_default_backend() -> Result<Box<dyn ExecBackend>> {
    reference_backend()
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn kernel_artifact_roundtrip() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        let spec = rt.manifest().artifact("k_sk_linear").unwrap().clone();
        // Zero inputs → output should equal the (zero) bias broadcast.
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        let out = rt.execute("k_sk_linear", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0].data().iter().all(|&v| v == 0.0));
        assert_eq!(rt.exec_count("k_sk_linear"), 1);
        assert_eq!(rt.cached_executables(), 1);
    }

    #[test]
    fn execute_rejects_bad_arity_and_shape() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt
            .execute("k_sk_linear", &[HostTensor::zeros(&[1])])
            .is_err());
        let spec = rt.manifest().artifact("k_sk_linear").unwrap().clone();
        let mut inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        inputs[0] = HostTensor::zeros(&[3, 3]);
        assert!(rt.execute("k_sk_linear", &inputs).is_err());
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(dir).unwrap();
        assert!(rt.execute("no_such_artifact", &[]).is_err());
    }
}
