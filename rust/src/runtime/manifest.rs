//! `manifest.json` model: what the AOT pipeline produced.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// One named input/output tensor of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// One executable artifact. `path` points at the lowered HLO text for the
/// PJRT backend; `ref_config` tells the in-crate reference backend which
/// builtin graph (and hyper-parameters) the artifact corresponds to. Either
/// may be vestigial depending on which backend executes the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Raw `"ref"` object from the manifest (`Json::Null` when absent).
    pub ref_config: Json,
}

impl ArtifactSpec {
    /// Index of a named input.
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }
}

/// A model variant: init/train/eval(/predict) artifact names + metadata.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    pub family: String,
    pub init: String,
    pub train: Option<String>,
    pub eval: Option<String>,
    /// Per-row eval (the serving/batcher path).
    pub eval_rows: Option<String>,
    pub predict: Option<String>,
    pub param_names: Vec<String>,
    pub param_count: usize,
    /// Raw config object (batch, seq, sketch, lr, …).
    pub config: Json,
}

impl ModelSpec {
    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key)?.as_usize()
    }

    pub fn config_f64(&self, key: &str) -> Option<f64> {
        self.config.get(key)?.as_f64()
    }

    /// Sketch config `(l, k)` or None for dense variants.
    pub fn sketch(&self) -> Option<(usize, usize)> {
        match self.config.get("sketch") {
            Some(Json::Arr(a)) if a.len() == 2 => {
                Some((a[0].as_usize()?, a[1].as_usize()?))
            }
            _ => None,
        }
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    artifacts: BTreeMap<String, ArtifactSpec>,
    models: BTreeMap<String, ModelSpec>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).context("manifest.json parse error")?;
        let mut artifacts = BTreeMap::new();
        let arts = root
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing 'artifacts'")?;
        for (name, spec) in arts {
            let parse_tensors = |key: &str, with_names: bool| -> Result<Vec<TensorSpec>> {
                let arr = spec
                    .get(key)
                    .and_then(Json::as_arr)
                    .with_context(|| format!("artifact {name} missing '{key}'"))?;
                arr.iter()
                    .enumerate()
                    .map(|(i, t)| {
                        let shape = t
                            .get("shape")
                            .and_then(Json::as_arr)
                            .context("tensor missing shape")?
                            .iter()
                            .map(|d| d.as_usize().context("bad dim"))
                            .collect::<Result<Vec<_>>>()?;
                        let tname = if with_names {
                            t.get("name")
                                .and_then(Json::as_str)
                                .unwrap_or("")
                                .to_string()
                        } else {
                            format!("out{i}")
                        };
                        Ok(TensorSpec { name: tname, shape })
                    })
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    path: spec
                        .get("path")
                        .and_then(Json::as_str)
                        .context("artifact missing path")?
                        .to_string(),
                    inputs: parse_tensors("inputs", true)?,
                    outputs: parse_tensors("outputs", false)?,
                    ref_config: spec.get("ref").cloned().unwrap_or(Json::Null),
                },
            );
        }
        let mut models = BTreeMap::new();
        if let Some(ms) = root.get("models").and_then(Json::as_obj) {
            for (name, spec) in ms {
                let get_str =
                    |k: &str| spec.get(k).and_then(Json::as_str).map(|s| s.to_string());
                models.insert(
                    name.clone(),
                    ModelSpec {
                        name: name.clone(),
                        family: get_str("family").unwrap_or_default(),
                        init: get_str("init").context("model missing init")?,
                        train: get_str("train"),
                        eval: get_str("eval"),
                        eval_rows: get_str("eval_rows"),
                        predict: get_str("predict"),
                        param_names: spec
                            .get("param_names")
                            .and_then(Json::as_arr)
                            .map(|a| {
                                a.iter()
                                    .filter_map(|v| v.as_str().map(|s| s.to_string()))
                                    .collect()
                            })
                            .unwrap_or_default(),
                        param_count: spec
                            .get("param_count")
                            .and_then(Json::as_usize)
                            .unwrap_or(0),
                        config: spec.get("config").cloned().unwrap_or(Json::Null),
                    },
                );
            }
        }
        Ok(Manifest { artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.get(name)
    }

    pub fn artifact_names(&self) -> impl Iterator<Item = &str> {
        self.artifacts.keys().map(|s| s.as_str())
    }

    pub fn model(&self, name: &str) -> Option<&ModelSpec> {
        self.models.get(name)
    }

    pub fn model_names(&self) -> impl Iterator<Item = &str> {
        self.models.keys().map(|s| s.as_str())
    }

    /// All model variants of a family (`bert`, `conv`), dense first.
    pub fn models_in_family(&self, family: &str) -> Vec<&ModelSpec> {
        let mut v: Vec<&ModelSpec> = self
            .models
            .values()
            .filter(|m| m.family == family)
            .collect();
        v.sort_by_key(|m| (m.sketch().is_some(), m.name.clone()));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "artifacts": {
        "toy_eval": {
          "path": "toy_eval.hlo.txt",
          "inputs": [
            {"name": "params.w", "shape": [4, 2]},
            {"name": "x", "shape": [8, 4]}
          ],
          "outputs": [{"shape": []}]
        }
      },
      "models": {
        "toy": {
          "family": "bert",
          "init": "toy_init",
          "train": null,
          "eval": "toy_eval",
          "param_names": ["w"],
          "param_count": 8,
          "config": {"batch": 8, "sketch": [1, 4], "lr": 0.001}
        }
      }
    }"#;

    #[test]
    fn parses_artifacts_and_models() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("toy_eval").unwrap();
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].name, "params.w");
        assert_eq!(a.inputs[0].shape, vec![4, 2]);
        assert_eq!(a.input_index("x"), Some(1));
        assert_eq!(a.outputs.len(), 1);
        assert!(a.outputs[0].shape.is_empty());

        let model = m.model("toy").unwrap();
        assert_eq!(model.eval.as_deref(), Some("toy_eval"));
        assert_eq!(model.train, None);
        assert_eq!(model.sketch(), Some((1, 4)));
        assert_eq!(model.config_usize("batch"), Some(8));
        assert!((model.config_f64("lr").unwrap() - 1e-3).abs() < 1e-12);
    }

    #[test]
    fn missing_sections_error() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn family_listing_orders_dense_first() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.models_in_family("bert").len(), 1);
        assert_eq!(m.models_in_family("conv").len(), 0);
    }
}
