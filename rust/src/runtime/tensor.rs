//! Host-side tensors exchanged with executable artifacts.

use crate::linalg::Mat;

/// A row-major f32 tensor with explicit shape. The execution backends
/// consume and produce these at the artifact boundary (the PJRT backend
/// converts to/from `xla::Literal`s, the reference backend reads the flat
/// storage directly); `Mat` converts for the 2-D case so the linalg
/// substrate and both execution paths interoperate.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} / data {} mismatch",
            data.len()
        );
        HostTensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn scalar(v: f32) -> Self {
        HostTensor {
            shape: vec![],
            data: vec![v],
        }
    }

    /// Fill with i.i.d. N(0, σ²) entries.
    pub fn randn<R: crate::rng::Rng>(shape: &[usize], sigma: f32, rng: &mut R) -> Self {
        let mut t = Self::zeros(shape);
        crate::rng::fill_normal(rng, &mut t.data);
        for v in &mut t.data {
            *v *= sigma;
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Scalar extraction (shape [] or [1]).
    pub fn to_scalar(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "to_scalar on shape {:?}", self.shape);
        self.data[0]
    }

    /// 2-D view as a `Mat` (copies).
    pub fn to_mat(&self) -> Mat {
        assert_eq!(self.shape.len(), 2, "to_mat on shape {:?}", self.shape);
        Mat::from_vec(self.shape[0], self.shape[1], self.data.clone())
    }

    pub fn from_mat(m: &Mat) -> Self {
        HostTensor::new(&[m.rows(), m.cols()], m.data().to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn construction_invariants() {
        let t = HostTensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
        let s = HostTensor::scalar(5.0);
        assert_eq!(s.to_scalar(), 5.0);
        assert!(s.shape().is_empty());
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn shape_data_mismatch_panics() {
        let _ = HostTensor::new(&[2, 2], vec![0.0; 3]);
    }

    #[test]
    fn mat_roundtrip() {
        let mut rng = Philox::seeded(7);
        let m = Mat::randn(4, 6, &mut rng);
        let t = HostTensor::from_mat(&m);
        assert_eq!(t.to_mat(), m);
    }
}
