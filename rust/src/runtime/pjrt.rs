//! PJRT execution backend (cargo feature `pjrt`): compiles the AOT HLO text
//! artifacts produced by `make artifacts` through the `xla` crate's PJRT CPU
//! client. This is the production execution path; the offline default build
//! uses [`super::backend::ReferenceBackend`] instead.

use super::backend::ExecBackend;
use super::manifest::ArtifactSpec;
use super::tensor::HostTensor;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::Path;

/// PJRT CPU client + compiled-executable cache. Not `Send` (raw C pointers),
/// so a `Runtime` holding it lives on one thread; the coordinator owns it on
/// a dedicated service thread and multiplexes requests over channels.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        Ok(PjrtBackend {
            client: xla::PjRtClient::cpu()?,
            cache: HashMap::new(),
        })
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn load(&mut self, spec: &ArtifactSpec, dir: &Path) -> Result<()> {
        if self.cache.contains_key(&spec.name) {
            return Ok(());
        }
        let path = dir.join(&spec.path);
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache.insert(spec.name.clone(), exe);
        Ok(())
    }

    fn execute(&mut self, spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let Some(exe) = self.cache.get(&spec.name) else {
            bail!("artifact {} executed before load", spec.name);
        };
        let literals: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?;
        // return_tuple=True → single tuple output on replica 0.
        let out_lit = result[0][0].to_literal_sync()?;
        let parts = out_lit.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "artifact {}: manifest declares {} outputs, HLO returned {}",
                spec.name,
                spec.outputs.len(),
                parts.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, os)| from_literal(lit, &os.shape))
            .collect()
    }
}

/// Convert to an `xla::Literal` (f32, row-major).
fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data());
    if t.shape().is_empty() {
        // Scalars: reshape to rank-0.
        Ok(lit.reshape(&[])?)
    } else {
        let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

/// Read back from a literal, validating the element count against the
/// expected shape from the manifest.
fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<HostTensor> {
    let data = lit.to_vec::<f32>()?;
    anyhow::ensure!(
        data.len() == shape.iter().product::<usize>(),
        "literal has {} elements, manifest shape {shape:?}",
        data.len()
    );
    Ok(HostTensor::new(shape, data))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let t = HostTensor::new(&[2, 3], (0..6).map(|i| i as f32).collect());
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit, &[2, 3]).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = HostTensor::scalar(3.5);
        let lit = to_literal(&t).unwrap();
        let back = from_literal(&lit, &[]).unwrap();
        assert_eq!(back.to_scalar(), 3.5);
    }
}
