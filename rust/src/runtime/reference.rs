//! Reference execution backend: runs the manifest's graphs directly on the
//! in-crate [`crate::linalg`] substrate, so the whole runtime stack —
//! coordinator, dynamic batcher, trainers, tuner — works offline with no
//! PJRT/XLA dependency.
//!
//! Each artifact carries a `"ref"` config object in `manifest.json` naming a
//! builtin graph plus its hyper-parameters. Implemented graphs:
//!
//! - `sk_linear`, `performer` — the two compute kernels, same math as the
//!   Pallas kernels (`python/compile/kernels/`).
//! - `bert_{init,train,eval,eval_rows}` — a BERT-mini stand-in for MLM:
//!   tied-embedding MLP `E → relu(X·W1) → ·W2 → ·Eᵀ → softmax`, masked
//!   cross-entropy, full analytic backward pass, Adam. Sketched variants
//!   replace `W1`/`W2` with the paper's `(1/l)·Σ U_j·V_j` two-factor form
//!   and train the factors directly.
//! - `conv_{init,train,predict}` — the image-classifier family (MLP over
//!   pixels; the reference backend trades the convolution structure for a
//!   correct, dependency-free gradient).
//!
//! Gradients were validated against finite differences (see the sign test
//! below; the full check lives in the development prototype), and every
//! graph is a pure deterministic function of its inputs — training runs are
//! bit-reproducible and per-row scores are independent of batch composition,
//! which the integration tests rely on.

use super::manifest::ArtifactSpec;
use super::tensor::HostTensor;
use crate::linalg::{matmul, matmul_nt, matmul_tn, Mat};
use crate::rng::Philox;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

/// Adam hyper-parameters (match the AOT train graphs).
const BETA1: f32 = 0.9;
const BETA2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;

const KNOWN_GRAPHS: [&str; 9] = [
    "sk_linear",
    "performer",
    "bert_init",
    "bert_train",
    "bert_eval",
    "bert_eval_rows",
    "conv_init",
    "conv_train",
    "conv_predict",
];

/// Load-time validation: the reference analogue of a compile error.
pub(crate) fn check(spec: &ArtifactSpec) -> Result<()> {
    let graph = graph_name(spec)?;
    if !KNOWN_GRAPHS.contains(&graph) {
        bail!(
            "artifact {}: unknown reference graph '{graph}' (known: {KNOWN_GRAPHS:?})",
            spec.name
        );
    }
    Ok(())
}

pub(crate) fn execute(spec: &ArtifactSpec, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    match graph_name(spec)? {
        "sk_linear" => kern_sk_linear(inputs),
        "performer" => kern_performer(inputs),
        "bert_init" => bert_init(&BertCfg::parse(spec)?, inputs),
        "bert_train" => bert_train(&BertCfg::parse(spec)?, inputs),
        "bert_eval" => bert_eval(&BertCfg::parse(spec)?, inputs, false),
        "bert_eval_rows" => bert_eval(&BertCfg::parse(spec)?, inputs, true),
        "conv_init" => conv_init(&ConvCfg::parse(spec)?, inputs),
        "conv_train" => conv_train(&ConvCfg::parse(spec)?, inputs),
        "conv_predict" => conv_predict(&ConvCfg::parse(spec)?, inputs),
        g => bail!("artifact {}: unknown reference graph '{g}'", spec.name),
    }
}

fn graph_name(spec: &ArtifactSpec) -> Result<&str> {
    spec.ref_config
        .get("graph")
        .and_then(Json::as_str)
        .with_context(|| {
            format!(
                "artifact {} has no reference config ('ref'.graph) — it can only run on the \
                 PJRT backend (rebuild artifacts with `make artifacts` and enable --features pjrt)",
                spec.name
            )
        })
}

fn cfg_usize(spec: &ArtifactSpec, key: &str) -> Result<usize> {
    spec.ref_config
        .get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("artifact {}: ref config missing '{key}'", spec.name))
}

fn cfg_sketch(spec: &ArtifactSpec) -> Option<(usize, usize)> {
    match spec.ref_config.get("sketch") {
        Some(Json::Arr(a)) if a.len() == 2 => Some((a[0].as_usize()?, a[1].as_usize()?)),
        _ => None,
    }
}

fn cfg_lr(spec: &ArtifactSpec) -> f32 {
    spec.ref_config
        .get("lr")
        .and_then(Json::as_f64)
        .unwrap_or(1e-3) as f32
}

// ---------------------------------------------------------------- helpers --

/// Split a stacked rank-3 factor tensor `[l, a, b]` into `l` matrices.
fn split_factors(t: &HostTensor) -> Result<Vec<Mat>> {
    let s = t.shape();
    anyhow::ensure!(s.len() == 3, "factor tensor must be rank-3, got {s:?}");
    let (l, a, b) = (s[0], s[1], s[2]);
    anyhow::ensure!(l > 0, "factor tensor has zero terms");
    Ok((0..l)
        .map(|j| Mat::from_vec(a, b, t.data()[j * a * b..(j + 1) * a * b].to_vec()))
        .collect())
}

/// Re-stack `l` equally-shaped matrices into a `[l, a, b]` tensor.
fn stack_factors(mats: &[Mat]) -> HostTensor {
    let (a, b) = mats[0].shape();
    let mut data = Vec::with_capacity(mats.len() * a * b);
    for m in mats {
        data.extend_from_slice(m.data());
    }
    HostTensor::new(&[mats.len(), a, b], data)
}

fn relu(a: &Mat) -> Mat {
    let mut r = a.clone();
    for v in r.data_mut() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
    r
}

/// Row-wise softmax in place (max-subtracted for stability).
fn softmax_rows(mut logits: Mat) -> Mat {
    for i in 0..logits.rows() {
        let row = logits.row_mut(i);
        let mut mx = f32::NEG_INFINITY;
        for &v in row.iter() {
            mx = mx.max(v);
        }
        let mut sum = 0f32;
        for v in row.iter_mut() {
            *v = (*v - mx).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
    logits
}

/// Sketched linear apply `(1/l)·Σ (x·U_j)·V_j`; returns the output and the
/// cached `x·U_j` intermediates the backward pass reuses.
fn sk_apply(x: &Mat, u: &[Mat], v: &[Mat]) -> (Mat, Vec<Mat>) {
    let l = u.len();
    let mut xu = Vec::with_capacity(l);
    let mut out = Mat::zeros(x.rows(), v[0].cols());
    for j in 0..l {
        let xj = matmul(x, &u[j]);
        out.axpy(1.0 / l as f32, &matmul(&xj, &v[j]));
        xu.push(xj);
    }
    (out, xu)
}

/// Backward through a sketched linear layer. Returns `(du, dv, dx_upstream)`.
fn sk_backward(x: &Mat, xu: &[Mat], u: &[Mat], v: &[Mat], dout: &Mat) -> (Vec<Mat>, Vec<Mat>, Mat) {
    let l = u.len();
    let inv_l = 1.0 / l as f32;
    let mut du = Vec::with_capacity(l);
    let mut dv = Vec::with_capacity(l);
    let mut dx = Mat::zeros(x.rows(), x.cols());
    for j in 0..l {
        // dout flows through V_jᵀ into the k-dim intermediate.
        let dmid = matmul_nt(dout, &v[j]); // rows × k
        du.push(matmul_tn(x, &dmid).scale(inv_l));
        dv.push(matmul_tn(&xu[j], dout).scale(inv_l));
        dx.axpy(inv_l, &matmul_nt(&dmid, &u[j]));
    }
    (du, dv, dx)
}

/// One Adam update; returns `(params', m', v')` without mutating inputs.
fn adam(
    p: &HostTensor,
    m: &HostTensor,
    v: &HostTensor,
    g: &HostTensor,
    step: f32,
    lr: f32,
) -> (HostTensor, HostTensor, HostTensor) {
    let t = step.max(1.0);
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    let mut pn = p.clone();
    let mut mn = m.clone();
    let mut vn = v.clone();
    let gd = g.data();
    let pd = pn.data_mut();
    let md = mn.data_mut();
    let vd = vn.data_mut();
    for i in 0..gd.len() {
        md[i] = BETA1 * md[i] + (1.0 - BETA1) * gd[i];
        vd[i] = BETA2 * vd[i] + (1.0 - BETA2) * gd[i] * gd[i];
        let mh = md[i] / bc1;
        let vh = vd[i] / bc2;
        pd[i] -= lr * mh / (vh.sqrt() + ADAM_EPS);
    }
    (pn, mn, vn)
}

/// Masked mean cross-entropy over all rows of `p` (softmax probabilities).
fn masked_mean_loss(p: &Mat, labels: &[f32], mask: &[f32], vocab: usize) -> f32 {
    let mut lsum = 0f64;
    let mut msum = 0f64;
    for i in 0..p.rows() {
        let m = mask[i] as f64;
        if m > 0.0 {
            let lab = (labels[i] as usize).min(vocab - 1);
            lsum += m * -(p.get(i, lab) as f64).max(1e-30).ln();
            msum += m;
        }
    }
    if msum > 0.0 {
        (lsum / msum) as f32
    } else {
        0.0
    }
}

// ---------------------------------------------------------------- kernels --

/// `y = (1/l)·Σ_j (x·U_j)·V_j + bias` — identical op sequence to the Rust
/// reference in the integration tests, so the paths agree bit-for-bit.
fn kern_sk_linear(inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    anyhow::ensure!(inputs.len() == 4, "sk_linear expects (x, u, v, bias)");
    let x = inputs[0].to_mat();
    let u = split_factors(&inputs[1])?;
    let v = split_factors(&inputs[2])?;
    anyhow::ensure!(u.len() == v.len(), "u/v term count mismatch");
    let bias = inputs[3].data();
    let (mut y, _xu) = sk_apply(&x, &u, &v);
    for i in 0..y.rows() {
        for (val, &b) in y.row_mut(i).iter_mut().zip(bias) {
            *val += b;
        }
    }
    Ok(vec![HostTensor::from_mat(&y)])
}

/// Single-head FAVOR+ linear attention `φ(Q)·(φ(K)ᵀV) / (φ(Q)·φ(K)ᵀ1)` with
/// the positive softmax feature map (global stabilizer per block).
fn kern_performer(inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    anyhow::ensure!(inputs.len() == 4, "performer expects (q, k, v, omega)");
    let q = inputs[0].to_mat();
    let k = inputs[1].to_mat();
    let v = inputs[2].to_mat();
    let omega = inputs[3].to_mat();
    let m = omega.cols();
    let scale = 1.0 / (m as f32).sqrt();
    let phi = |x: &Mat| -> Mat {
        let proj = matmul(x, &omega);
        let mx = proj
            .data()
            .iter()
            .cloned()
            .fold(f32::NEG_INFINITY, f32::max);
        let mut out = Mat::zeros(proj.rows(), proj.cols());
        for i in 0..proj.rows() {
            let sq: f32 = x.row(i).iter().map(|&a| a * a).sum::<f32>() / 2.0;
            for (o, &pv) in out.row_mut(i).iter_mut().zip(proj.row(i)) {
                *o = (pv - sq - mx).exp() * scale;
            }
        }
        out
    };
    let pq = phi(&q);
    let pk = phi(&k);
    let kv = matmul_tn(&pk, &v); // m × d_h
    let mut z = vec![0f32; m];
    for i in 0..pk.rows() {
        for (zj, &pj) in z.iter_mut().zip(pk.row(i)) {
            *zj += pj;
        }
    }
    let num = matmul(&pq, &kv);
    let mut out = Mat::zeros(q.rows(), v.cols());
    for i in 0..out.rows() {
        let den: f32 = pq
            .row(i)
            .iter()
            .zip(&z)
            .map(|(&a, &b)| a * b)
            .sum::<f32>()
            .max(1e-9);
        for (o, &nv) in out.row_mut(i).iter_mut().zip(num.row(i)) {
            *o = nv / den;
        }
    }
    Ok(vec![HostTensor::from_mat(&out)])
}

// ------------------------------------------------------------- bert family --

struct BertCfg {
    vocab: usize,
    dim: usize,
    hidden: usize,
    lr: f32,
    sketch: Option<(usize, usize)>,
}

impl BertCfg {
    fn parse(spec: &ArtifactSpec) -> Result<BertCfg> {
        Ok(BertCfg {
            vocab: cfg_usize(spec, "vocab")?,
            dim: cfg_usize(spec, "dim")?,
            hidden: cfg_usize(spec, "hidden")?,
            lr: cfg_lr(spec),
            sketch: cfg_sketch(spec),
        })
    }

    fn n_params(&self) -> usize {
        if self.sketch.is_some() {
            5
        } else {
            3
        }
    }
}

/// Unpacked BERT weights: the embedding plus either dense or factored FCs.
struct BertParams {
    e: Mat,
    dense: Option<(Mat, Mat)>,
    sk: Option<(Vec<Mat>, Vec<Mat>, Vec<Mat>, Vec<Mat>)>,
}

fn unpack_bert(cfg: &BertCfg, params: &[HostTensor]) -> Result<BertParams> {
    anyhow::ensure!(
        params.len() == cfg.n_params(),
        "bert params arity {} != {}",
        params.len(),
        cfg.n_params()
    );
    let e = params[0].to_mat();
    anyhow::ensure!(e.shape() == (cfg.vocab, cfg.dim), "tok_emb shape");
    if cfg.sketch.is_some() {
        Ok(BertParams {
            e,
            dense: None,
            sk: Some((
                split_factors(&params[1])?,
                split_factors(&params[2])?,
                split_factors(&params[3])?,
                split_factors(&params[4])?,
            )),
        })
    } else {
        Ok(BertParams {
            e,
            dense: Some((params[1].to_mat(), params[2].to_mat())),
            sk: None,
        })
    }
}

/// Forward activations cached for the backward pass.
struct BertAct {
    tok: Vec<usize>,
    x: Mat,
    a: Mat,
    r: Mat,
    z: Mat,
    p: Mat,
    xu: Vec<Mat>,
    ru: Vec<Mat>,
}

fn bert_forward(cfg: &BertCfg, w: &BertParams, tokens: &HostTensor) -> BertAct {
    let n = tokens.len();
    let d = cfg.dim;
    let tok: Vec<usize> = tokens
        .data()
        .iter()
        .map(|&t| (t as usize).min(cfg.vocab - 1))
        .collect();
    let mut x = Mat::zeros(n, d);
    for (i, &t) in tok.iter().enumerate() {
        x.row_mut(i).copy_from_slice(w.e.row(t));
    }
    let (a, xu) = match (&w.dense, &w.sk) {
        (Some((w1, _)), _) => (matmul(&x, w1), Vec::new()),
        (None, Some((u1, v1, _, _))) => sk_apply(&x, u1, v1),
        _ => unreachable!("unpack_bert always fills one variant"),
    };
    let r = relu(&a);
    let (z, ru) = match (&w.dense, &w.sk) {
        (Some((_, w2)), _) => (matmul(&r, w2), Vec::new()),
        (None, Some((_, _, u2, v2))) => sk_apply(&r, u2, v2),
        _ => unreachable!(),
    };
    // Tied head: logits = Z·Eᵀ.
    let p = softmax_rows(matmul_nt(&z, &w.e));
    BertAct {
        tok,
        x,
        a,
        r,
        z,
        p,
        xu,
        ru,
    }
}

fn bert_init(cfg: &BertCfg, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    anyhow::ensure!(inputs.len() == 1, "init expects the seed scalar");
    let seed = inputs[0].to_scalar();
    let mut rng = Philox::seeded(seed.to_bits() as u64);
    let (v, d, h) = (cfg.vocab, cfg.dim, cfg.hidden);
    let mut params = vec![HostTensor::randn(&[v, d], 0.02, &mut rng)];
    match cfg.sketch {
        None => {
            params.push(HostTensor::randn(&[d, h], (2.0 / d as f32).sqrt(), &mut rng));
            params.push(HostTensor::randn(&[h, d], (2.0 / h as f32).sqrt(), &mut rng));
        }
        Some((l, k)) => {
            let su = (1.0 / k as f32).sqrt();
            params.push(HostTensor::randn(&[l, d, k], su, &mut rng));
            params.push(HostTensor::randn(&[l, k, h], (2.0 / d as f32).sqrt(), &mut rng));
            params.push(HostTensor::randn(&[l, h, k], su, &mut rng));
            params.push(HostTensor::randn(&[l, k, d], (2.0 / h as f32).sqrt(), &mut rng));
        }
    }
    let m: Vec<HostTensor> = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    let v: Vec<HostTensor> = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    Ok(params.into_iter().chain(m).chain(v).collect())
}

fn bert_eval(cfg: &BertCfg, inputs: &[HostTensor], per_row: bool) -> Result<Vec<HostTensor>> {
    let n = cfg.n_params();
    anyhow::ensure!(
        inputs.len() == n + 3,
        "bert eval expects params + (tokens, labels, mask)"
    );
    let w = unpack_bert(cfg, &inputs[..n])?;
    let (tokens, labels, mask) = (&inputs[n], &inputs[n + 1], &inputs[n + 2]);
    let act = bert_forward(cfg, &w, tokens);
    if per_row {
        let (b, s) = (tokens.shape()[0], tokens.shape()[1]);
        let mut out = vec![0f32; b];
        for (bi, o) in out.iter_mut().enumerate() {
            let mut lsum = 0f64;
            let mut msum = 0f64;
            for si in 0..s {
                let i = bi * s + si;
                let m = mask.data()[i] as f64;
                if m > 0.0 {
                    let lab = (labels.data()[i] as usize).min(cfg.vocab - 1);
                    lsum += m * -(act.p.get(i, lab) as f64).max(1e-30).ln();
                    msum += m;
                }
            }
            *o = if msum > 0.0 { (lsum / msum) as f32 } else { 0.0 };
        }
        Ok(vec![HostTensor::new(&[b], out)])
    } else {
        let loss = masked_mean_loss(&act.p, labels.data(), mask.data(), cfg.vocab);
        Ok(vec![HostTensor::scalar(loss)])
    }
}

fn bert_train(cfg: &BertCfg, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let n = cfg.n_params();
    anyhow::ensure!(
        inputs.len() == 3 * n + 4,
        "bert train expects params, m, v, step, tokens, labels, mask"
    );
    let (params, rest) = inputs.split_at(n);
    let (mom, rest) = rest.split_at(n);
    let (vel, rest) = rest.split_at(n);
    let step = rest[0].to_scalar();
    let (tokens, labels, mask) = (&rest[1], &rest[2], &rest[3]);
    let w = unpack_bert(cfg, params)?;
    let act = bert_forward(cfg, &w, tokens);
    let loss = masked_mean_loss(&act.p, labels.data(), mask.data(), cfg.vocab);

    let wsum: f64 = mask.data().iter().map(|&m| m as f64).sum();
    let grads: Vec<HostTensor> = if wsum == 0.0 {
        params.iter().map(|t| HostTensor::zeros(t.shape())).collect()
    } else {
        // dL = (softmax − onehot) · mask/Σmask, row-wise.
        let mut dl = act.p.clone();
        for i in 0..dl.rows() {
            let lab = (labels.data()[i] as usize).min(cfg.vocab - 1);
            let wi = (mask.data()[i] as f64 / wsum) as f32;
            let row = dl.row_mut(i);
            row[lab] -= 1.0;
            for v in row.iter_mut() {
                *v *= wi;
            }
        }
        let dz = matmul(&dl, &w.e); // N × D
        let mut de = matmul_tn(&dl, &act.z); // tied-head part, V × D
        // Layer 2 backward.
        let (dr, l2_grads) = match (&w.dense, &w.sk) {
            (Some((_, w2)), _) => {
                let dw2 = matmul_tn(&act.r, &dz);
                (matmul_nt(&dz, w2), vec![HostTensor::from_mat(&dw2)])
            }
            (None, Some((_, _, u2, v2))) => {
                let (du2, dv2, dr) = sk_backward(&act.r, &act.ru, u2, v2, &dz);
                (dr, vec![stack_factors(&du2), stack_factors(&dv2)])
            }
            _ => unreachable!(),
        };
        let mut da = dr;
        for (dv, &av) in da.data_mut().iter_mut().zip(act.a.data()) {
            if av <= 0.0 {
                *dv = 0.0;
            }
        }
        // Layer 1 backward.
        let (dx, l1_grads) = match (&w.dense, &w.sk) {
            (Some((w1, _)), _) => {
                let dw1 = matmul_tn(&act.x, &da);
                (matmul_nt(&da, w1), vec![HostTensor::from_mat(&dw1)])
            }
            (None, Some((u1, v1, _, _))) => {
                let (du1, dv1, dx) = sk_backward(&act.x, &act.xu, u1, v1, &da);
                (dx, vec![stack_factors(&du1), stack_factors(&dv1)])
            }
            _ => unreachable!(),
        };
        // Embedding scatter: lookup gradient adds to the tied-head gradient.
        for (i, &t) in act.tok.iter().enumerate() {
            for (dv, &xv) in de.row_mut(t).iter_mut().zip(dx.row(i)) {
                *dv += xv;
            }
        }
        let mut grads = vec![HostTensor::from_mat(&de)];
        grads.extend(l1_grads);
        grads.extend(l2_grads);
        grads
    };

    let mut out_p = Vec::with_capacity(n);
    let mut out_m = Vec::with_capacity(n);
    let mut out_v = Vec::with_capacity(n);
    for i in 0..n {
        let (p2, m2, v2) = adam(&params[i], &mom[i], &vel[i], &grads[i], step, cfg.lr);
        out_p.push(p2);
        out_m.push(m2);
        out_v.push(v2);
    }
    let mut out: Vec<HostTensor> = out_p;
    out.extend(out_m);
    out.extend(out_v);
    out.push(HostTensor::scalar(loss));
    Ok(out)
}

// ------------------------------------------------------------- conv family --

struct ConvCfg {
    classes: usize,
    px: usize,
    hidden: usize,
    lr: f32,
    sketch: Option<(usize, usize)>,
}

impl ConvCfg {
    fn parse(spec: &ArtifactSpec) -> Result<ConvCfg> {
        Ok(ConvCfg {
            classes: cfg_usize(spec, "classes")?,
            px: cfg_usize(spec, "px")?,
            hidden: cfg_usize(spec, "hidden")?,
            lr: cfg_lr(spec),
            sketch: cfg_sketch(spec),
        })
    }

    fn n_params(&self) -> usize {
        if self.sketch.is_some() {
            3
        } else {
            2
        }
    }
}

struct ConvParams {
    w1: Option<Mat>,
    fac1: Option<(Vec<Mat>, Vec<Mat>)>,
    w2: Mat,
}

fn unpack_conv(cfg: &ConvCfg, params: &[HostTensor]) -> Result<ConvParams> {
    anyhow::ensure!(
        params.len() == cfg.n_params(),
        "conv params arity {} != {}",
        params.len(),
        cfg.n_params()
    );
    if cfg.sketch.is_some() {
        Ok(ConvParams {
            w1: None,
            fac1: Some((split_factors(&params[0])?, split_factors(&params[1])?)),
            w2: params[2].to_mat(),
        })
    } else {
        Ok(ConvParams {
            w1: Some(params[0].to_mat()),
            fac1: None,
            w2: params[1].to_mat(),
        })
    }
}

struct ConvAct {
    x: Mat,
    a: Mat,
    r: Mat,
    logits: Mat,
    xu: Vec<Mat>,
}

fn conv_forward(w: &ConvParams, images: &HostTensor) -> ConvAct {
    let x = images.to_mat();
    let (a, xu) = match (&w.w1, &w.fac1) {
        (Some(w1), _) => (matmul(&x, w1), Vec::new()),
        (None, Some((u1, v1))) => sk_apply(&x, u1, v1),
        _ => unreachable!(),
    };
    let r = relu(&a);
    let logits = matmul(&r, &w.w2);
    ConvAct { x, a, r, logits, xu }
}

fn conv_init(cfg: &ConvCfg, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    anyhow::ensure!(inputs.len() == 1, "init expects the seed scalar");
    let seed = inputs[0].to_scalar();
    let mut rng = Philox::seeded(seed.to_bits() as u64 ^ 0xC04F);
    let (px, h, c) = (cfg.px, cfg.hidden, cfg.classes);
    let mut params = Vec::new();
    match cfg.sketch {
        None => {
            params.push(HostTensor::randn(&[px, h], (2.0 / px as f32).sqrt(), &mut rng));
        }
        Some((l, k)) => {
            params.push(HostTensor::randn(&[l, px, k], (1.0 / k as f32).sqrt(), &mut rng));
            params.push(HostTensor::randn(&[l, k, h], (2.0 / px as f32).sqrt(), &mut rng));
        }
    }
    params.push(HostTensor::randn(&[h, c], (2.0 / h as f32).sqrt(), &mut rng));
    let m: Vec<HostTensor> = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    let v: Vec<HostTensor> = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    Ok(params.into_iter().chain(m).chain(v).collect())
}

fn conv_predict(cfg: &ConvCfg, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let n = cfg.n_params();
    anyhow::ensure!(inputs.len() == n + 1, "predict expects params + images");
    let w = unpack_conv(cfg, &inputs[..n])?;
    let act = conv_forward(&w, &inputs[n]);
    Ok(vec![HostTensor::from_mat(&act.logits)])
}

fn conv_train(cfg: &ConvCfg, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
    let n = cfg.n_params();
    anyhow::ensure!(
        inputs.len() == 3 * n + 3,
        "conv train expects params, m, v, step, images, labels"
    );
    let (params, rest) = inputs.split_at(n);
    let (mom, rest) = rest.split_at(n);
    let (vel, rest) = rest.split_at(n);
    let step = rest[0].to_scalar();
    let (images, labels) = (&rest[1], &rest[2]);
    let w = unpack_conv(cfg, params)?;
    let act = conv_forward(&w, images);
    let p = softmax_rows(act.logits.clone());
    let b = p.rows();
    let labs: Vec<usize> = labels
        .data()
        .iter()
        .map(|&l| (l as usize).min(cfg.classes - 1))
        .collect();
    let mut loss = 0f64;
    for (i, &lab) in labs.iter().enumerate() {
        loss += -(p.get(i, lab) as f64).max(1e-30).ln();
    }
    let loss = (loss / b as f64) as f32;
    // dL = (softmax − onehot)/B.
    let mut dl = p;
    for (i, &lab) in labs.iter().enumerate() {
        let row = dl.row_mut(i);
        row[lab] -= 1.0;
        for v in row.iter_mut() {
            *v /= b as f32;
        }
    }
    let dw2 = matmul_tn(&act.r, &dl);
    let mut da = matmul_nt(&dl, &w.w2);
    for (dv, &av) in da.data_mut().iter_mut().zip(act.a.data()) {
        if av <= 0.0 {
            *dv = 0.0;
        }
    }
    let mut grads: Vec<HostTensor> = match (&w.w1, &w.fac1) {
        (Some(_), _) => vec![HostTensor::from_mat(&matmul_tn(&act.x, &da))],
        (None, Some((u1, v1))) => {
            let (du1, dv1, _dx) = sk_backward(&act.x, &act.xu, u1, v1, &da);
            vec![stack_factors(&du1), stack_factors(&dv1)]
        }
        _ => unreachable!(),
    };
    grads.push(HostTensor::from_mat(&dw2));

    let mut out_p = Vec::with_capacity(n);
    let mut out_m = Vec::with_capacity(n);
    let mut out_v = Vec::with_capacity(n);
    for i in 0..n {
        let (p2, m2, v2) = adam(&params[i], &mom[i], &vel[i], &grads[i], step, cfg.lr);
        out_p.push(p2);
        out_m.push(m2);
        out_v.push(v2);
    }
    let mut out: Vec<HostTensor> = out_p;
    out.extend(out_m);
    out.extend(out_v);
    out.push(HostTensor::scalar(loss));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    fn bert_spec(graph: &str, sketch: Option<(usize, usize)>) -> ArtifactSpec {
        let mut r = Json::obj();
        r.set("graph", graph)
            .set("vocab", 12usize)
            .set("dim", 5usize)
            .set("hidden", 7usize)
            .set("lr", 0.05);
        if let Some((l, k)) = sketch {
            r.set("sketch", vec![l as i64, k as i64]);
        }
        ArtifactSpec {
            name: format!("test_{graph}"),
            path: "builtin".into(),
            inputs: vec![],
            outputs: vec![],
            ref_config: r,
        }
    }

    fn conv_spec(graph: &str, sketch: Option<(usize, usize)>) -> ArtifactSpec {
        let mut r = Json::obj();
        r.set("graph", graph)
            .set("classes", 4usize)
            .set("px", 9usize)
            .set("hidden", 6usize)
            .set("lr", 0.05);
        if let Some((l, k)) = sketch {
            r.set("sketch", vec![l as i64, k as i64]);
        }
        ArtifactSpec {
            name: format!("test_{graph}"),
            path: "builtin".into(),
            inputs: vec![],
            outputs: vec![],
            ref_config: r,
        }
    }

    fn fake_batch(vocab: usize, b: usize, s: usize, seed: u64) -> (HostTensor, HostTensor, HostTensor) {
        use crate::rng::Rng;
        let mut rng = Philox::seeded(seed);
        let tokens: Vec<f32> = (0..b * s)
            .map(|_| (2 + rng.next_below(vocab as u32 - 2)) as f32)
            .collect();
        let labels = tokens.clone();
        let mask: Vec<f32> = (0..b * s)
            .map(|_| if rng.next_f32() < 0.3 { 1.0 } else { 0.0 })
            .collect();
        (
            HostTensor::new(&[b, s], tokens),
            HostTensor::new(&[b, s], labels),
            HostTensor::new(&[b, s], mask),
        )
    }

    fn run_init(spec: &ArtifactSpec, seed: f32) -> Vec<HostTensor> {
        execute(spec, &[HostTensor::scalar(seed)]).unwrap()
    }

    fn eval_loss(cfg_spec: &ArtifactSpec, params: &[HostTensor], batch: &(HostTensor, HostTensor, HostTensor)) -> f32 {
        let mut inputs: Vec<HostTensor> = params.to_vec();
        inputs.push(batch.0.clone());
        inputs.push(batch.1.clone());
        inputs.push(batch.2.clone());
        execute(cfg_spec, &inputs).unwrap()[0].to_scalar()
    }

    /// Random params at O(1) scale so gradients are well above the f32
    /// finite-difference noise floor.
    fn big_params(sketch: Option<(usize, usize)>, seed: u64) -> Vec<HostTensor> {
        let mut rng = Philox::seeded(seed);
        let (v, d, h) = (12, 5, 7);
        let mut params = vec![HostTensor::randn(&[v, d], 0.4, &mut rng)];
        match sketch {
            None => {
                params.push(HostTensor::randn(&[d, h], 0.5, &mut rng));
                params.push(HostTensor::randn(&[h, d], 0.5, &mut rng));
            }
            Some((l, k)) => {
                params.push(HostTensor::randn(&[l, d, k], 0.5, &mut rng));
                params.push(HostTensor::randn(&[l, k, h], 0.5, &mut rng));
                params.push(HostTensor::randn(&[l, h, k], 0.5, &mut rng));
                params.push(HostTensor::randn(&[l, k, d], 0.5, &mut rng));
            }
        }
        params
    }

    /// After one Adam step from zero moments, Δp ≈ −lr·sign(g); check that
    /// sign against a finite-difference gradient through the eval loss.
    #[test]
    fn bert_train_step_descends_finite_difference_gradient() {
        for sketch in [None, Some((2usize, 3usize))] {
            let train = bert_spec("bert_train", sketch);
            let evals = bert_spec("bert_eval", sketch);
            let params = big_params(sketch, 5);
            let n = params.len();
            let state: Vec<HostTensor> = params
                .iter()
                .cloned()
                .chain(params.iter().map(|t| HostTensor::zeros(t.shape())))
                .chain(params.iter().map(|t| HostTensor::zeros(t.shape())))
                .collect();
            let batch = fake_batch(12, 2, 6, 3);
            // One train step.
            let mut inputs: Vec<HostTensor> = state.to_vec();
            inputs.push(HostTensor::scalar(1.0));
            inputs.push(batch.0.clone());
            inputs.push(batch.1.clone());
            inputs.push(batch.2.clone());
            let out = execute(&train, &inputs).unwrap();
            assert_eq!(out.len(), 3 * n + 1);
            let loss0 = out.last().unwrap().to_scalar();
            assert!(loss0.is_finite() && loss0 > 0.0);
            // Finite-difference a few coordinates of each parameter.
            let eps = 2e-3f32;
            let mut checked = 0;
            for pi in 0..n {
                for idx in [0usize, params[pi].len() / 2] {
                    let mut plus = params.to_vec();
                    plus[pi].data_mut()[idx] += eps;
                    let lp = eval_loss(&evals, &plus, &batch);
                    let mut minus = params.to_vec();
                    minus[pi].data_mut()[idx] -= eps;
                    let lm = eval_loss(&evals, &minus, &batch);
                    let fd = (lp - lm) / (2.0 * eps);
                    if fd.abs() < 1e-3 {
                        continue; // too flat for a reliable sign
                    }
                    let delta = out[pi].data()[idx] - params[pi].data()[idx];
                    assert!(
                        (delta < 0.0) == (fd > 0.0),
                        "sketch {sketch:?} param {pi} idx {idx}: step {delta} vs fd grad {fd}"
                    );
                    checked += 1;
                }
            }
            assert!(checked >= 3, "too few informative coordinates ({checked})");
        }
    }

    #[test]
    fn bert_training_reduces_loss() {
        let init = bert_spec("bert_init", None);
        let train = bert_spec("bert_train", None);
        let mut state = run_init(&init, 1.0);
        let n = state.len() / 3;
        let batch = fake_batch(12, 4, 8, 9);
        let mut first = f32::NAN;
        let mut last = f32::NAN;
        for step in 1..=30 {
            let mut inputs: Vec<HostTensor> = state.clone();
            inputs.push(HostTensor::scalar(step as f32));
            inputs.push(batch.0.clone());
            inputs.push(batch.1.clone());
            inputs.push(batch.2.clone());
            let out = execute(&train, &inputs).unwrap();
            last = out.last().unwrap().to_scalar();
            if step == 1 {
                first = last;
            }
            state = out[..3 * n].to_vec();
        }
        assert!(
            last < first - 0.2,
            "repeated-batch loss should fall: {first} → {last}"
        );
    }

    #[test]
    fn eval_rows_match_whole_batch_semantics() {
        let init = bert_spec("bert_init", None);
        let rows = bert_spec("bert_eval_rows", None);
        let state = run_init(&init, 2.0);
        let n = state.len() / 3;
        let params = &state[..n];
        let batch = fake_batch(12, 3, 6, 11);
        let mut inputs: Vec<HostTensor> = params.to_vec();
        inputs.push(batch.0.clone());
        inputs.push(batch.1.clone());
        inputs.push(batch.2.clone());
        let per_row = execute(&rows, &inputs).unwrap().remove(0);
        assert_eq!(per_row.shape(), &[3]);
        // At least one row must carry masked positions; pick the busiest so
        // the comparison below is non-vacuous.
        let busiest = (0..3)
            .max_by(|&a, &b| {
                let msum = |r: usize| -> f32 { batch.2.data()[r * 6..(r + 1) * 6].iter().sum() };
                msum(a).partial_cmp(&msum(b)).unwrap()
            })
            .unwrap();
        assert!(per_row.data()[busiest] > 0.0, "test batch has no masked row");
        // That row alone (every other row zero-masked) must score
        // identically — the composition-independence the dynamic batcher
        // relies on.
        let mut mask_solo = HostTensor::zeros(&[3, 6]);
        mask_solo.data_mut()[busiest * 6..(busiest + 1) * 6]
            .copy_from_slice(&batch.2.data()[busiest * 6..(busiest + 1) * 6]);
        let mut solo_inputs: Vec<HostTensor> = params.to_vec();
        solo_inputs.push(batch.0.clone());
        solo_inputs.push(batch.1.clone());
        solo_inputs.push(mask_solo);
        let solo = execute(&rows, &solo_inputs).unwrap().remove(0);
        assert_eq!(solo.data()[busiest], per_row.data()[busiest]);
        for r in 0..3 {
            if r != busiest {
                assert_eq!(solo.data()[r], 0.0);
            }
        }
    }

    #[test]
    fn conv_training_reduces_loss_and_predicts() {
        for sketch in [None, Some((1usize, 2usize))] {
            let init = conv_spec("conv_init", sketch);
            let train = conv_spec("conv_train", sketch);
            let predict = conv_spec("conv_predict", sketch);
            let mut state = run_init(&init, 3.0);
            let n = state.len() / 3;
            // Deterministic toy batch: class = argmax pixel block.
            let bsz = 8;
            let mut images = vec![0f32; bsz * 9];
            let mut labels = vec![0f32; bsz];
            for i in 0..bsz {
                let c = i % 4;
                labels[i] = c as f32;
                images[i * 9 + c * 2] = 1.0;
            }
            let images = HostTensor::new(&[bsz, 9], images);
            let labels = HostTensor::new(&[bsz], labels);
            let mut first = f32::NAN;
            let mut last = f32::NAN;
            for step in 1..=60 {
                let mut inputs: Vec<HostTensor> = state.clone();
                inputs.push(HostTensor::scalar(step as f32));
                inputs.push(images.clone());
                inputs.push(labels.clone());
                let out = execute(&train, &inputs).unwrap();
                last = out.last().unwrap().to_scalar();
                if step == 1 {
                    first = last;
                }
                state = out[..3 * n].to_vec();
            }
            assert!(last < first, "sketch {sketch:?}: {first} → {last}");
            let mut inputs: Vec<HostTensor> = state[..n].to_vec();
            inputs.push(images.clone());
            let logits = execute(&predict, &inputs).unwrap().remove(0);
            assert_eq!(logits.shape(), &[bsz, 4]);
        }
    }

    #[test]
    fn kernels_match_rust_reference_bitwise() {
        let mut rng = Philox::seeded(7);
        let x = HostTensor::randn(&[4, 6], 0.5, &mut rng);
        let u = HostTensor::randn(&[2, 6, 3], 0.5, &mut rng);
        let v = HostTensor::randn(&[2, 3, 5], 0.5, &mut rng);
        let bias = HostTensor::randn(&[5], 0.5, &mut rng);
        let spec = ArtifactSpec {
            name: "k".into(),
            path: "builtin".into(),
            inputs: vec![],
            outputs: vec![],
            ref_config: {
                let mut r = Json::obj();
                r.set("graph", "sk_linear");
                r
            },
        };
        let out = execute(&spec, &[x.clone(), u.clone(), v.clone(), bias.clone()]).unwrap();
        let mut expect = Mat::zeros(4, 5);
        for j in 0..2 {
            let uj = Mat::from_vec(6, 3, u.data()[j * 18..(j + 1) * 18].to_vec());
            let vj = Mat::from_vec(3, 5, v.data()[j * 15..(j + 1) * 15].to_vec());
            expect.axpy(0.5, &matmul(&matmul(&x.to_mat(), &uj), &vj));
        }
        for i in 0..4 {
            for (val, &b) in expect.row_mut(i).iter_mut().zip(bias.data()) {
                *val += b;
            }
        }
        assert_eq!(out[0].data(), expect.data());
    }
}
