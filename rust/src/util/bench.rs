//! Criterion-style micro-benchmark statistics (criterion itself is not
//! available offline). Provides warm-up, adaptive sample counts, robust
//! statistics, a stable one-line report format that the figure benches
//! and EXPERIMENTS.md rely on, and [`JsonReport`] — the machine-readable
//! `BENCH_*.json` emitter that seeds the perf trajectory every later
//! performance PR is judged against.

use crate::util::json::Json;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// Result of one benchmark: robust timing statistics over N samples.
#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub samples: usize,
    pub mean: Duration,
    pub median: Duration,
    pub stddev: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean.as_secs_f64() * 1e3
    }

    pub fn median_ms(&self) -> f64 {
        self.median.as_secs_f64() * 1e3
    }

    /// One-line report: `name  mean ± stddev  (median, N samples)`.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:<10} (median {:>12}, n={})",
            self.name,
            super::human_duration(self.mean),
            super::human_duration(self.stddev),
            super::human_duration(self.median),
            self.samples
        )
    }
}

/// Benchmark runner configuration.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum number of timed samples.
    pub min_samples: usize,
    /// Maximum number of timed samples.
    pub max_samples: usize,
    /// Target total measurement time; sampling stops at whichever of
    /// max_samples / target_time comes last after min_samples.
    pub target_time: Duration,
    /// Warm-up time before measurement.
    pub warmup: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            min_samples: 10,
            max_samples: 200,
            target_time: Duration::from_secs(2),
            warmup: Duration::from_millis(300),
        }
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end benches.
    pub fn quick() -> Self {
        Bencher {
            min_samples: 5,
            max_samples: 30,
            target_time: Duration::from_millis(800),
            warmup: Duration::from_millis(100),
        }
    }

    /// Paper preset: the paper reports the mean over 200 repeated trials.
    pub fn paper() -> Self {
        Bencher {
            min_samples: 20,
            max_samples: 200,
            target_time: Duration::from_secs(3),
            warmup: Duration::from_millis(300),
        }
    }

    /// Time `f` repeatedly; `f` should perform one complete operation and
    /// return a value (returned values are black-boxed to stop DCE).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> Stats {
        // Warm-up.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples: Vec<Duration> = Vec::with_capacity(self.max_samples);
        let t0 = Instant::now();
        while samples.len() < self.min_samples
            || (samples.len() < self.max_samples && t0.elapsed() < self.target_time)
        {
            let s = Instant::now();
            black_box(f());
            samples.push(s.elapsed());
        }
        stats_from(name, &mut samples)
    }
}

/// Compute statistics from raw samples.
pub fn stats_from(name: &str, samples: &mut [Duration]) -> Stats {
    assert!(!samples.is_empty());
    samples.sort();
    let n = samples.len();
    let sum: Duration = samples.iter().sum();
    let mean = sum / n as u32;
    let median = samples[n / 2];
    let mean_s = mean.as_secs_f64();
    let var = samples
        .iter()
        .map(|d| (d.as_secs_f64() - mean_s).powi(2))
        .sum::<f64>()
        / n as f64;
    Stats {
        name: name.to_string(),
        samples: n,
        mean,
        median,
        stddev: Duration::from_secs_f64(var.sqrt()),
        min: samples[0],
        max: samples[n - 1],
    }
}

/// Opaque value sink: prevents the optimizer from deleting the benched work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Machine-readable bench results: one entry per (op, shape) with the
/// measured milliseconds, optional GFLOP/s, and the kernel thread count —
/// written as `BENCH_<name>.json` at the repo root so perf regressions
/// are diffable across PRs (`gemm_kernels` writes `BENCH_gemm.json`,
/// `e2e_runtime` writes `BENCH_e2e.json`).
pub struct JsonReport {
    bench: String,
    threads: usize,
    entries: Vec<Json>,
}

impl JsonReport {
    /// New report for bench `name`, recording `threads` kernel workers
    /// (pass [`crate::linalg::gemm_threads()`]).
    pub fn new(name: &str, threads: usize) -> Self {
        JsonReport {
            bench: name.to_string(),
            threads,
            entries: Vec::new(),
        }
    }

    /// Record one measurement. `gflops` is `2·m·k·n / seconds / 1e9` for
    /// GEMM-shaped ops, `None` where a FLOP rate is meaningless.
    pub fn entry(&mut self, op: &str, shape: &str, ms: f64, gflops: Option<f64>) {
        self.entry_with(op, shape, ms, &[]);
        if let Some(g) = gflops {
            if let Some(e) = self.entries.last_mut() {
                e.set("gflops", g);
            }
        }
    }

    /// [`JsonReport::entry`] plus arbitrary extra numeric fields (e.g.
    /// the serve bench's `rps`, admitted `workers`, memory bytes) —
    /// measurements that aren't a milliseconds-or-GFLOP/s shape still
    /// belong in the machine-readable trajectory.
    pub fn entry_with(&mut self, op: &str, shape: &str, ms: f64, extra: &[(&str, f64)]) {
        let mut e = Json::obj();
        e.set("op", op).set("shape", shape).set("ms", ms);
        for (k, v) in extra {
            e.set(k, *v);
        }
        self.entries.push(e);
    }

    /// Record one pre-built entry object (e.g. a
    /// [`crate::serve::TierSnapshot`] serialized via `to_json`) — callers
    /// with richer shapes than (op, shape, ms) still land in the same
    /// `entries` array CI diffs.
    pub fn push_entry(&mut self, entry: Json) {
        self.entries.push(entry);
    }

    /// Serialized report document.
    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set("bench", self.bench.as_str())
            .set("threads", self.threads)
            .set("entries", Json::Arr(self.entries.clone()));
        doc
    }

    /// Write `BENCH_<name>.json` into the bench output directory:
    /// `$PANTHER_BENCH_DIR` if set, else the nearest ancestor of the
    /// current directory containing `.git` (the repo root — benches run
    /// from `rust/`), else the current directory. Returns the path
    /// written.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let dir = match std::env::var_os("PANTHER_BENCH_DIR") {
            Some(d) => PathBuf::from(d),
            None => repo_root_or_cwd(),
        };
        let path = dir.join(format!("BENCH_{}.json", self.bench));
        std::fs::write(&path, self.to_json().to_pretty() + "\n")?;
        Ok(path)
    }
}

/// Nearest ancestor of the current directory containing `.git`, else `.`.
fn repo_root_or_cwd() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir: &Path = &cwd;
    loop {
        if dir.join(".git").exists() {
            return dir.to_path_buf();
        }
        match dir.parent() {
            Some(p) => dir = p,
            None => return cwd.clone(),
        }
    }
}

/// A simple table printer for bench suites: aligned columns, markdown-ish.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(r[c].len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, cell) in cells.iter().enumerate() {
                s.push_str(&format!(" {:<w$} |", cell, w = widths[c]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}-|", "-".repeat(w + 1)));
        }
        out.push('\n');
        for r in &self.rows {
            out.push_str(&line(r, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering_invariants() {
        let b = Bencher {
            min_samples: 5,
            max_samples: 10,
            target_time: Duration::from_millis(50),
            warmup: Duration::from_millis(1),
        };
        let s = b.run("noop", || 1 + 1);
        assert!(s.min <= s.median);
        assert!(s.median <= s.max);
        assert!(s.samples >= 5);
    }

    #[test]
    fn stats_from_known_values() {
        let mut samples = vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(3),
        ];
        let s = stats_from("x", &mut samples);
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.mean, Duration::from_millis(2));
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
    }

    #[test]
    fn json_report_roundtrips_through_the_parser() {
        let mut r = JsonReport::new("unit", 4);
        r.entry("gemm", "64x64x64", 0.123, Some(4.26));
        r.entry("attention_fwd", "n=128 d=64 h=8", 1.5, None);
        r.entry_with("throughput", "cap=8", 0.9, &[("rps", 1234.5), ("workers", 3.0)]);
        let doc = Json::parse(&r.to_json().to_pretty()).unwrap();
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("unit"));
        assert_eq!(doc.get("threads").and_then(Json::as_usize), Some(4));
        let entries = doc.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].get("op").and_then(Json::as_str), Some("gemm"));
        assert!(entries[0].get("gflops").and_then(Json::as_f64).unwrap() > 4.0);
        assert!(entries[1].get("gflops").is_none());
        assert_eq!(entries[2].get("rps").and_then(Json::as_f64), Some(1234.5));
        assert_eq!(entries[2].get("workers").and_then(Json::as_usize), Some(3));
    }

    #[test]
    fn json_report_writes_to_env_dir() {
        let dir = std::env::temp_dir().join("panther_bench_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        // Write explicitly against the temp dir rather than the env var
        // (tests run in parallel; mutating the process env would race).
        let mut r = JsonReport::new("smoke", 1);
        r.entry("noop", "-", 0.0, None);
        let path = dir.join("BENCH_smoke.json");
        std::fs::write(&path, r.to_json().to_pretty()).unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("smoke"));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "ms"]);
        t.row(&["dense".into(), "12.5".into()]);
        t.row(&["sketched_k16".into(), "3.1".into()]);
        let r = t.render();
        assert!(r.contains("| name"));
        assert!(r.lines().count() == 4);
    }
}
