//! Peak-memory accounting for the Figure-3 reproduction.
//!
//! The paper reports *peak forward memory* of attention variants and marks
//! configurations that OOM on the GPU with an "x". We reproduce that with an
//! explicit accounting arena: every buffer an attention implementation
//! allocates is registered here, and a configurable budget turns
//! would-be-OOM configurations into a clean [`MemError::BudgetExceeded`] —
//! the same semantics as CUDA's allocator failing, without crashing the
//! bench process.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Error from the tracking allocator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MemError {
    BudgetExceeded {
        requested: u64,
        live: u64,
        budget: u64,
    },
}

impl std::fmt::Display for MemError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MemError::BudgetExceeded {
                requested,
                live,
                budget,
            } => write!(
                f,
                "memory budget exceeded: requested {requested} B with {live} B live (budget {budget} B)"
            ),
        }
    }
}

impl std::error::Error for MemError {}

/// Shared accounting state. Cloneable handle.
#[derive(Clone, Debug)]
pub struct MemTracker {
    inner: Arc<Inner>,
}

#[derive(Debug)]
struct Inner {
    live: AtomicU64,
    peak: AtomicU64,
    budget: u64, // 0 = unlimited
}

impl MemTracker {
    /// Unlimited tracker (pure accounting).
    pub fn unlimited() -> Self {
        Self::with_budget(0)
    }

    /// Tracker that fails allocations pushing `live` above `budget` bytes.
    pub fn with_budget(budget: u64) -> Self {
        MemTracker {
            inner: Arc::new(Inner {
                live: AtomicU64::new(0),
                peak: AtomicU64::new(0),
                budget,
            }),
        }
    }

    /// Account an allocation of `bytes`; returns a guard that releases on
    /// drop. Fails if the budget would be exceeded.
    pub fn alloc(&self, bytes: u64) -> Result<MemGuard, MemError> {
        let live = self.inner.live.fetch_add(bytes, Ordering::SeqCst) + bytes;
        if self.inner.budget != 0 && live > self.inner.budget {
            self.inner.live.fetch_sub(bytes, Ordering::SeqCst);
            return Err(MemError::BudgetExceeded {
                requested: bytes,
                live: live - bytes,
                budget: self.inner.budget,
            });
        }
        self.inner.peak.fetch_max(live, Ordering::SeqCst);
        Ok(MemGuard {
            tracker: self.clone(),
            bytes,
        })
    }

    /// Allocate a tracked f32 buffer of `len` elements.
    pub fn alloc_f32(&self, len: usize) -> Result<TrackedBuf, MemError> {
        let guard = self.alloc((len * std::mem::size_of::<f32>()) as u64)?;
        Ok(TrackedBuf {
            data: vec![0f32; len],
            _guard: guard,
        })
    }

    pub fn live_bytes(&self) -> u64 {
        self.inner.live.load(Ordering::SeqCst)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.inner.peak.load(Ordering::SeqCst)
    }

    /// Reset the peak to the current live value (between bench cases).
    pub fn reset_peak(&self) {
        self.inner
            .peak
            .store(self.inner.live.load(Ordering::SeqCst), Ordering::SeqCst);
    }
}

/// RAII guard: releases its byte count when dropped.
#[derive(Debug)]
pub struct MemGuard {
    tracker: MemTracker,
    bytes: u64,
}

impl Drop for MemGuard {
    fn drop(&mut self) {
        self.tracker.inner.live.fetch_sub(self.bytes, Ordering::SeqCst);
    }
}

/// An f32 buffer whose lifetime is tied to its accounting guard.
pub struct TrackedBuf {
    pub data: Vec<f32>,
    _guard: MemGuard,
}

impl std::ops::Deref for TrackedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl std::ops::DerefMut for TrackedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_live_and_peak() {
        let t = MemTracker::unlimited();
        let a = t.alloc(100).unwrap();
        assert_eq!(t.live_bytes(), 100);
        let b = t.alloc(50).unwrap();
        assert_eq!(t.live_bytes(), 150);
        assert_eq!(t.peak_bytes(), 150);
        drop(a);
        assert_eq!(t.live_bytes(), 50);
        assert_eq!(t.peak_bytes(), 150, "peak survives frees");
        drop(b);
        assert_eq!(t.live_bytes(), 0);
    }

    #[test]
    fn budget_enforced() {
        let t = MemTracker::with_budget(1000);
        let _a = t.alloc(800).unwrap();
        let err = t.alloc(300).unwrap_err();
        assert!(matches!(err, MemError::BudgetExceeded { .. }));
        // Failed alloc must not leak accounting.
        assert_eq!(t.live_bytes(), 800);
        // Freeing makes room.
        drop(_a);
        assert!(t.alloc(900).is_ok());
    }

    #[test]
    fn tracked_buf_accounts_elements() {
        let t = MemTracker::unlimited();
        {
            let mut buf = t.alloc_f32(256).unwrap();
            buf[0] = 1.0;
            assert_eq!(t.live_bytes(), 1024);
        }
        assert_eq!(t.live_bytes(), 0);
    }

    #[test]
    fn reset_peak() {
        let t = MemTracker::unlimited();
        let a = t.alloc(100).unwrap();
        drop(a);
        assert_eq!(t.peak_bytes(), 100);
        t.reset_peak();
        assert_eq!(t.peak_bytes(), 0);
    }
}
