//! Structured event primitives for the serve-stack tracing layer
//! ([`crate::serve::trace`]): a typed event vocabulary, a bounded ring
//! buffer, a token-bucket rate limiter with exact per-class accounting, and
//! a wall-clock stage profiler.
//!
//! Everything here is `std`-only and independent of the serve layer so the
//! profiler can also be threaded through `nn` forwards and `linalg::gemm`
//! without a dependency cycle.

use crate::util::bench::Table;
use crate::util::lock_ignore_poison;
use crate::util::log::Level;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The event vocabulary of the serve stack. Per-request classes trace one
/// request's path (admission → reply); tier-level classes (recorded with
/// trace id 0) describe the machinery around it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum EventClass {
    /// Request admitted; detail carries the pinned model version.
    Admit = 0,
    /// Span from enqueue to batch execution start (queue + coalesce wait).
    QueueWait = 1,
    /// Span covering the batched model forward the request rode in.
    Exec = 2,
    /// Output transform (softmax/top-k) applied to the request's batch.
    Transform = 3,
    /// Terminal: reply sent with an `Ok` payload.
    Reply = 4,
    /// Terminal: reply sent with a typed error; detail names the kind.
    Error = 5,
    /// Cascade routed the request below the best eligible rung.
    Shed = 6,
    /// Cascade found no rung that can meet the deadline (tier-level).
    SloReject = 7,
    /// Speculative fast+verify pair launched; detail links the fast leg.
    Speculate = 8,
    /// Speculative verify leg settled with an upgraded answer.
    Upgrade = 9,
    /// Speculative verify leg failed or was dropped; fast answer stands.
    Revoke = 10,
    /// Quarantine bisection re-executed a sub-batch (tier-level).
    Quarantine = 11,
    /// A row struck out of quarantine as a confirmed poison input.
    Poisoned = 12,
    /// Numeric guard rejected non-finite output rows.
    NonFinite = 13,
    /// Model hot-swap published a new version (tier-level).
    Swap = 14,
    /// Supervisor respawned a dead worker (tier-level).
    Restart = 15,
    /// Fault injection armed for a batch (tier-level; detail says what).
    Fault = 16,
}

impl EventClass {
    pub const COUNT: usize = 17;

    /// Every class, indexable by `class as usize`.
    pub const ALL: [EventClass; EventClass::COUNT] = [
        EventClass::Admit,
        EventClass::QueueWait,
        EventClass::Exec,
        EventClass::Transform,
        EventClass::Reply,
        EventClass::Error,
        EventClass::Shed,
        EventClass::SloReject,
        EventClass::Speculate,
        EventClass::Upgrade,
        EventClass::Revoke,
        EventClass::Quarantine,
        EventClass::Poisoned,
        EventClass::NonFinite,
        EventClass::Swap,
        EventClass::Restart,
        EventClass::Fault,
    ];

    pub fn name(self) -> &'static str {
        match self {
            EventClass::Admit => "admit",
            EventClass::QueueWait => "queue_wait",
            EventClass::Exec => "exec",
            EventClass::Transform => "transform",
            EventClass::Reply => "reply",
            EventClass::Error => "error",
            EventClass::Shed => "shed",
            EventClass::SloReject => "slo_reject",
            EventClass::Speculate => "speculate",
            EventClass::Upgrade => "upgrade",
            EventClass::Revoke => "revoke",
            EventClass::Quarantine => "quarantine",
            EventClass::Poisoned => "poisoned",
            EventClass::NonFinite => "nonfinite",
            EventClass::Swap => "swap",
            EventClass::Restart => "restart",
            EventClass::Fault => "fault",
        }
    }

    /// Log severity for classes that should also surface through
    /// [`crate::util::log`] when recorded; `None` stays trace-only.
    pub fn severity(self) -> Option<Level> {
        match self {
            EventClass::Fault | EventClass::Restart | EventClass::Quarantine => Some(Level::Warn),
            EventClass::Poisoned | EventClass::NonFinite | EventClass::Error => {
                Some(Level::Error)
            }
            _ => None,
        }
    }
}

/// One structured event. `dur_us == 0` marks an instant; `trace == 0` marks
/// a tier-level event not attached to any single request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Event {
    /// Microseconds since the tracer started.
    pub t_us: u64,
    /// Span duration in microseconds (0 = instant event).
    pub dur_us: u64,
    pub class: EventClass,
    /// Trace id of the request this event belongs to (0 = tier-level).
    pub trace: u64,
    /// Free-form detail (`"v=3"`, `"kind=PoisonedInput"`, ...).
    pub detail: String,
}

/// Bounded FIFO ring of events. Pushing past capacity drops the oldest
/// event and counts it in `overflow` — recent history always survives a
/// storm; the counter keeps the loss honest.
pub struct EventRing {
    inner: Mutex<VecDeque<Event>>,
    cap: usize,
    dropped: AtomicU64,
}

impl EventRing {
    pub fn new(cap: usize) -> EventRing {
        EventRing {
            inner: Mutex::new(VecDeque::with_capacity(cap.max(1))),
            cap: cap.max(1),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn push(&self, e: Event) {
        let mut q = lock_ignore_poison(&self.inner);
        if q.len() == self.cap {
            q.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        q.push_back(e);
    }

    /// Oldest-first copy of the retained events.
    pub fn snapshot(&self) -> Vec<Event> {
        lock_ignore_poison(&self.inner).iter().cloned().collect()
    }

    /// Events evicted to make room (ring overflow, not rate limiting).
    pub fn overflow(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

struct BucketState {
    tokens: f64,
    last: Instant,
}

/// Classic token bucket: `capacity` burst tokens, refilled continuously at
/// `refill_per_sec`. With `refill_per_sec == 0.0` the bucket never refills —
/// exactly `capacity` takes succeed, which makes suppression tests
/// deterministic.
pub struct TokenBucket {
    capacity: f64,
    refill_per_sec: f64,
    state: Mutex<BucketState>,
}

impl TokenBucket {
    pub fn new(capacity: u64, refill_per_sec: f64) -> TokenBucket {
        TokenBucket {
            capacity: capacity as f64,
            refill_per_sec: refill_per_sec.max(0.0),
            state: Mutex::new(BucketState {
                tokens: capacity as f64,
                last: Instant::now(),
            }),
        }
    }

    /// Take one token if available.
    pub fn try_take(&self) -> bool {
        let mut st = lock_ignore_poison(&self.state);
        if self.refill_per_sec > 0.0 {
            let now = Instant::now();
            let dt = now.duration_since(st.last).as_secs_f64();
            st.tokens = (st.tokens + dt * self.refill_per_sec).min(self.capacity);
            st.last = now;
        }
        if st.tokens >= 1.0 {
            st.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Per-[`EventClass`] token buckets with exact accounting: every attempt is
/// counted as either recorded or suppressed, so
/// `recorded(c) + suppressed(c)` equals the number of `admit(c)` calls for
/// every class `c` — the invariant the trace tests assert.
pub struct ClassLimiter {
    buckets: Vec<TokenBucket>,
    recorded: Vec<AtomicU64>,
    suppressed: Vec<AtomicU64>,
}

impl ClassLimiter {
    pub fn new(capacity: u64, refill_per_sec: f64) -> ClassLimiter {
        ClassLimiter {
            buckets: (0..EventClass::COUNT)
                .map(|_| TokenBucket::new(capacity, refill_per_sec))
                .collect(),
            recorded: (0..EventClass::COUNT).map(|_| AtomicU64::new(0)).collect(),
            suppressed: (0..EventClass::COUNT).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Charge one event of `class`; `true` means record it, `false` means
    /// it was suppressed (and counted as such).
    pub fn admit(&self, class: EventClass) -> bool {
        let i = class as usize;
        if self.buckets[i].try_take() {
            self.recorded[i].fetch_add(1, Ordering::Relaxed);
            true
        } else {
            self.suppressed[i].fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    pub fn recorded(&self, class: EventClass) -> u64 {
        self.recorded[class as usize].load(Ordering::Relaxed)
    }

    pub fn suppressed(&self, class: EventClass) -> u64 {
        self.suppressed[class as usize].load(Ordering::Relaxed)
    }

    pub fn total_suppressed(&self) -> u64 {
        self.suppressed.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

/// Aggregate wall time of one named stage.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageStat {
    pub calls: u64,
    pub total_ns: u64,
}

impl StageStat {
    pub fn mean(&self) -> Duration {
        if self.calls == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.total_ns / self.calls)
        }
    }
}

/// Stage-level wall-clock profiler: named stages (`"layer/fc1"`,
/// `"gemm/pack"`, `"gemm/kernel"`) accumulate call counts and total time.
/// Attached behind an `Option` so the unprofiled path pays one branch.
#[derive(Default)]
pub struct StageProfiler {
    stages: Mutex<BTreeMap<String, StageStat>>,
}

impl StageProfiler {
    pub fn new() -> StageProfiler {
        StageProfiler::default()
    }

    pub fn record(&self, stage: &str, d: Duration) {
        let mut m = lock_ignore_poison(&self.stages);
        let s = m.entry(stage.to_string()).or_default();
        s.calls += 1;
        s.total_ns += d.as_nanos() as u64;
    }

    /// Alphabetical copy of the accumulated stages.
    pub fn snapshot(&self) -> BTreeMap<String, StageStat> {
        lock_ignore_poison(&self.stages).clone()
    }

    /// Human-readable table of stages, calls, total, and mean time.
    pub fn report(&self) -> String {
        let mut t = Table::new(&["stage", "calls", "total", "mean"]);
        for (name, s) in self.snapshot() {
            t.row(&[
                name,
                s.calls.to_string(),
                crate::util::human_duration(Duration::from_nanos(s.total_ns)),
                crate::util::human_duration(s.mean()),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_table_is_consistent() {
        assert_eq!(EventClass::ALL.len(), EventClass::COUNT);
        for (i, c) in EventClass::ALL.iter().enumerate() {
            assert_eq!(*c as usize, i);
        }
        // Names are unique (the exporters key on them).
        let mut names: Vec<_> = EventClass::ALL.iter().map(|c| c.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), EventClass::COUNT);
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let ring = EventRing::new(3);
        for i in 0..5u64 {
            ring.push(Event {
                t_us: i,
                dur_us: 0,
                class: EventClass::Admit,
                trace: i,
                detail: String::new(),
            });
        }
        let snap = ring.snapshot();
        assert_eq!(snap.iter().map(|e| e.t_us).collect::<Vec<_>>(), [2, 3, 4]);
        assert_eq!(ring.overflow(), 2);
    }

    #[test]
    fn zero_refill_bucket_is_exact() {
        let b = TokenBucket::new(3, 0.0);
        assert_eq!((0..10).filter(|_| b.try_take()).count(), 3);
    }

    #[test]
    fn limiter_accounting_is_exact() {
        let lim = ClassLimiter::new(2, 0.0);
        let attempts = 7u64;
        for _ in 0..attempts {
            lim.admit(EventClass::Fault);
        }
        assert_eq!(lim.recorded(EventClass::Fault), 2);
        assert_eq!(lim.suppressed(EventClass::Fault), attempts - 2);
        // Other classes untouched.
        assert_eq!(lim.recorded(EventClass::Reply), 0);
        assert_eq!(lim.total_suppressed(), attempts - 2);
    }

    #[test]
    fn refilling_bucket_recovers() {
        let b = TokenBucket::new(1, 1000.0);
        assert!(b.try_take());
        // Drained now; after ~2 ms at 1000 tokens/s at least one token is back.
        std::thread::sleep(Duration::from_millis(5));
        assert!(b.try_take());
    }

    #[test]
    fn profiler_accumulates() {
        let p = StageProfiler::new();
        p.record("gemm/pack", Duration::from_micros(10));
        p.record("gemm/pack", Duration::from_micros(20));
        p.record("layer/fc1", Duration::from_micros(5));
        let snap = p.snapshot();
        assert_eq!(snap["gemm/pack"].calls, 2);
        assert_eq!(snap["gemm/pack"].total_ns, 30_000);
        assert_eq!(snap["gemm/pack"].mean(), Duration::from_micros(15));
        assert_eq!(snap["layer/fc1"].calls, 1);
        let rep = p.report();
        assert!(rep.contains("gemm/pack") && rep.contains("layer/fc1"));
    }
}
