//! Tiny declarative CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! generated `--help` text. Used by `panther` (the binary) and the examples.

use std::collections::BTreeMap;

/// Parsed arguments: options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// One declared option, for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// A declarative command: name, summary, options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: true,
            help,
            default,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: false,
            help,
            default: None,
        });
        self
    }

    /// Parse raw argv (after the subcommand token). Unknown `--keys` error.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.opts.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key} (see --help)"))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} requires a value"))?
                        }
                    };
                    args.opts.insert(key, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{key} does not take a value"));
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Render help text.
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.takes_value {
                format!("  --{} <value>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<28} {}{def}\n", o.help));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("train", "train a model")
            .opt("steps", "number of steps", Some("100"))
            .opt("lr", "learning rate", Some("1e-3"))
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = cmd()
            .parse(&argv(&["--steps", "500", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("steps", 0), 500);
        assert_eq!(a.get_f64("lr", 0.0), 1e-3); // default preserved
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_syntax() {
        let a = cmd().parse(&argv(&["--steps=7"])).unwrap();
        assert_eq!(a.get_usize("steps", 0), 7);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&argv(&["--nope", "1"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cmd().parse(&argv(&["--steps"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&argv(&["--verbose=yes"])).is_err());
    }

    #[test]
    fn help_mentions_options() {
        let h = cmd().help();
        assert!(h.contains("--steps"));
        assert!(h.contains("default: 100"));
    }
}
