//! Leveled, timestamped logging to stderr with per-module level overrides
//! and an optional structured JSON-line output mode.
//!
//! `PANTHER_LOG` configures levels. Each comma-separated token is either a
//! bare level (`error|warn|info|debug|trace`) setting the default, or a
//! `module=level` override (`PANTHER_LOG=info,serve=debug`). An override
//! applies when any `::`-segment of the call site's `module_path!()` equals
//! the key (so `serve=debug` covers `panther::serve::batcher`); when several
//! tokens match, the last one wins.
//!
//! `PANTHER_LOG_FORMAT=json` switches output from the human-readable line to
//! one JSON object per line (`{"level":..,"module":..,"msg":..,"t_s":..}`),
//! escaped through [`crate::util::json`] so messages with quotes or control
//! characters stay machine-parseable.

use crate::util::json::Json;
use crate::util::lock_ignore_poison;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    /// Lowercase name (JSON output).
    fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
            Level::Trace => "trace",
        }
    }
}

struct LogConfig {
    default: Level,
    /// `(module-segment, level)` overrides in specification order.
    overrides: Vec<(String, Level)>,
    json: bool,
}

/// Cached `max(default, overrides)` for the lock-free rejection fast path.
/// 255 = configuration not yet loaded.
static MAX_LEVEL: AtomicU8 = AtomicU8::new(255);

static CONFIG: OnceLock<Mutex<LogConfig>> = OnceLock::new();

static START: OnceLock<Instant> = OnceLock::new();

/// Parse a `PANTHER_LOG` spec into `(default, overrides)`.
fn parse_spec(spec: &str) -> (Level, Vec<(String, Level)>) {
    let mut default = Level::Info;
    let mut overrides = Vec::new();
    for tok in spec.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        match tok.split_once('=') {
            Some((m, lv)) => overrides.push((m.trim().to_string(), Level::from_str(lv.trim()))),
            None => default = Level::from_str(tok),
        }
    }
    (default, overrides)
}

/// Effective level for a `module_path!()`-style module string: the default,
/// unless an override key equals the full path or any `::`-segment of it
/// (last matching override wins).
fn effective(cfg: &LogConfig, module: &str) -> Level {
    let mut lv = cfg.default;
    for (key, l) in &cfg.overrides {
        if module == key.as_str() || module.split("::").any(|seg| seg == key) {
            lv = *l;
        }
    }
    lv
}

fn max_of(cfg: &LogConfig) -> Level {
    cfg.overrides
        .iter()
        .map(|(_, l)| *l)
        .fold(cfg.default, Level::max)
}

fn config() -> &'static Mutex<LogConfig> {
    CONFIG.get_or_init(|| {
        let (default, overrides) = std::env::var("PANTHER_LOG")
            .map(|s| parse_spec(&s))
            .unwrap_or((Level::Info, Vec::new()));
        let json = std::env::var("PANTHER_LOG_FORMAT")
            .is_ok_and(|s| s.eq_ignore_ascii_case("json"));
        let cfg = LogConfig {
            default,
            overrides,
            json,
        };
        MAX_LEVEL.store(max_of(&cfg) as u8, Ordering::Relaxed);
        Mutex::new(cfg)
    })
}

/// Override the default level programmatically (tests, examples).
pub fn set_level(lv: Level) {
    let mut cfg = lock_ignore_poison(config());
    cfg.default = lv;
    MAX_LEVEL.store(max_of(&cfg) as u8, Ordering::Relaxed);
}

/// Add a per-module override programmatically, as if `module=level` had been
/// appended to `PANTHER_LOG`.
pub fn set_module_level(module: &str, lv: Level) {
    let mut cfg = lock_ignore_poison(config());
    cfg.overrides.push((module.to_string(), lv));
    MAX_LEVEL.store(max_of(&cfg) as u8, Ordering::Relaxed);
}

/// Switch JSON-line output on/off programmatically, as if
/// `PANTHER_LOG_FORMAT=json` had been set.
pub fn set_format_json(on: bool) {
    lock_ignore_poison(config()).json = on;
}

/// Core log call — prefer the macros.
pub fn log(lv: Level, module: &str, msg: &str) {
    // Lock-free fast path: nothing anywhere logs at this level.
    let max = MAX_LEVEL.load(Ordering::Relaxed);
    if max != 255 && lv as u8 > max {
        return;
    }
    let cfg = lock_ignore_poison(config());
    if lv > effective(&cfg, module) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    if cfg.json {
        let mut o = Json::obj();
        o.set("t_s", (t.as_secs_f64() * 1e3).round() / 1e3)
            .set("level", lv.name())
            .set("module", module)
            .set("msg", msg);
        eprintln!("{}", o.to_string());
    } else {
        eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), lv.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("error"), Level::Error);
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn spec_parsing() {
        let (d, o) = parse_spec("info,serve=debug, gemm = trace ,warn");
        // Last bare token wins for the default.
        assert_eq!(d, Level::Warn);
        assert_eq!(
            o,
            vec![
                ("serve".to_string(), Level::Debug),
                ("gemm".to_string(), Level::Trace)
            ]
        );
        let (d, o) = parse_spec("");
        assert_eq!(d, Level::Info);
        assert!(o.is_empty());
    }

    #[test]
    fn effective_module_levels() {
        let (default, overrides) = parse_spec("warn,serve=debug,batcher=error");
        let cfg = LogConfig {
            default,
            overrides,
            json: false,
        };
        // Segment match anywhere in the path.
        assert_eq!(effective(&cfg, "panther::serve"), Level::Debug);
        assert_eq!(effective(&cfg, "panther::serve::cascade"), Level::Debug);
        // Later override wins when both match.
        assert_eq!(effective(&cfg, "panther::serve::batcher"), Level::Error);
        // No match falls back to the default.
        assert_eq!(effective(&cfg, "panther::linalg::gemm"), Level::Warn);
        // The fast-path cache must admit the most verbose configured level.
        assert_eq!(max_of(&cfg), Level::Debug);
    }
}
