//! Leveled, timestamped logging to stderr. `PANTHER_LOG` selects the level
//! (`error|warn|info|debug|trace`, default `info`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Level {
        match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => Level::Info,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialized

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == 255 {
        let lv = std::env::var("PANTHER_LOG")
            .map(|s| Level::from_str(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(lv as u8, Ordering::Relaxed);
        lv
    } else {
        // SAFETY-free mapping: raw was stored from a valid Level.
        match raw {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            3 => Level::Debug,
            _ => Level::Trace,
        }
    }
}

/// Override the level programmatically (tests, examples).
pub fn set_level(lv: Level) {
    LEVEL.store(lv as u8, Ordering::Relaxed);
}

/// Core log call — prefer the macros.
pub fn log(lv: Level, module: &str, msg: &str) {
    if lv <= level() {
        let t = START.get_or_init(Instant::now).elapsed();
        eprintln!("[{:>9.3}s {} {}] {}", t.as_secs_f64(), lv.tag(), module, msg);
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("error"), Level::Error);
        assert_eq!(Level::from_str("TRACE"), Level::Trace);
        assert_eq!(Level::from_str("bogus"), Level::Info);
    }

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Debug < Level::Trace);
    }
}
