//! Minimal regular-expression engine (the `regex` crate is unavailable
//! offline). Supports the subset [`crate::nn::LayerSelector`] needs for
//! layer-path matching:
//!
//! - literals, `.` (any char), escaped metacharacters (`\.` `\(` …)
//! - Perl classes `\d \D \w \W \s \S`
//! - character classes `[a-z0-9_]`, negated `[^…]`, with ranges
//! - anchors `^` and `$`
//! - quantifiers `*` `+` `?` — greedy with backtracking, applying to a
//!   single-character atom (literal, `.`, or class)
//! - alternation `|` and (unquantified) groups `(…)`
//!
//! Unsupported constructs (quantified groups, `{n,m}` counts, captures,
//! lookaround) are rejected at compile time with a clear error, never
//! mis-matched silently.

use std::fmt;

/// Compile error for the mini regex engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RexError {
    pub msg: String,
}

impl fmt::Display for RexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "regex error: {}", self.msg)
    }
}

impl std::error::Error for RexError {}

#[derive(Debug, Clone, PartialEq)]
enum ClassItem {
    Single(char),
    Range(char, char),
    Digit(bool),
    Word(bool),
    Space(bool),
}

#[derive(Debug, Clone, PartialEq)]
enum Node {
    Char(char),
    Any,
    Class { neg: bool, items: Vec<ClassItem> },
    Start,
    End,
    Group(Vec<Vec<Node>>),
    Repeat {
        atom: Box<Node>,
        min: usize,
        max: Option<usize>,
    },
}

/// A compiled pattern. `is_match` searches for the pattern anywhere in the
/// input (use `^`/`$` to anchor), like `regex::Regex::is_match`.
#[derive(Debug, Clone)]
pub struct Regex {
    alts: Vec<Vec<Node>>,
    pattern: String,
}

impl Regex {
    pub fn new(pattern: &str) -> Result<Regex, RexError> {
        let chars: Vec<char> = pattern.chars().collect();
        let mut p = Parser {
            chars: &chars,
            pos: 0,
        };
        let alts = p.alternation()?;
        if p.pos != chars.len() {
            return Err(RexError {
                msg: format!("unexpected ')' at offset {}", p.pos),
            });
        }
        Ok(Regex {
            alts,
            pattern: pattern.to_string(),
        })
    }

    pub fn as_str(&self) -> &str {
        &self.pattern
    }

    /// Does the pattern match anywhere in `text`?
    pub fn is_match(&self, text: &str) -> bool {
        let chars: Vec<char> = text.chars().collect();
        (0..=chars.len()).any(|start| {
            self.alts
                .iter()
                .any(|seq| match_nodes(seq, 0, &chars, start, &Cont::Done))
        })
    }
}

struct Parser<'a> {
    chars: &'a [char],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: impl Into<String>) -> RexError {
        RexError { msg: msg.into() }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<char> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn alternation(&mut self) -> Result<Vec<Vec<Node>>, RexError> {
        let mut alts = Vec::new();
        loop {
            alts.push(self.sequence()?);
            if self.peek() == Some('|') {
                self.pos += 1;
            } else {
                break;
            }
        }
        Ok(alts)
    }

    fn sequence(&mut self) -> Result<Vec<Node>, RexError> {
        let mut seq = Vec::new();
        while let Some(c) = self.peek() {
            if c == '|' || c == ')' {
                break;
            }
            let node = self.atom()?;
            match self.peek() {
                Some(q @ ('*' | '+' | '?')) => {
                    self.pos += 1;
                    let quantifiable = matches!(
                        node,
                        Node::Char(_) | Node::Any | Node::Class { .. }
                    );
                    if !quantifiable {
                        return Err(self.err(format!(
                            "'{q}' may only follow a single-character atom (got {node:?})"
                        )));
                    }
                    let (min, max) = match q {
                        '*' => (0, None),
                        '+' => (1, None),
                        _ => (0, Some(1)),
                    };
                    seq.push(Node::Repeat {
                        atom: Box::new(node),
                        min,
                        max,
                    });
                }
                Some('{') => return Err(self.err("{n,m} quantifiers are not supported")),
                _ => seq.push(node),
            }
        }
        Ok(seq)
    }

    fn atom(&mut self) -> Result<Node, RexError> {
        let c = self.bump().ok_or_else(|| self.err("unexpected end"))?;
        match c {
            '^' => Ok(Node::Start),
            '$' => Ok(Node::End),
            '.' => Ok(Node::Any),
            '(' => {
                let alts = self.alternation()?;
                if self.bump() != Some(')') {
                    return Err(self.err("unclosed group"));
                }
                Ok(Node::Group(alts))
            }
            '[' => self.class(),
            '\\' => self.escape(),
            '*' | '+' | '?' => Err(self.err(format!("dangling quantifier '{c}'"))),
            c => Ok(Node::Char(c)),
        }
    }

    fn escape(&mut self) -> Result<Node, RexError> {
        let c = self
            .bump()
            .ok_or_else(|| self.err("trailing backslash"))?;
        let perl = |item: ClassItem| Node::Class {
            neg: false,
            items: vec![item],
        };
        match c {
            'd' => Ok(perl(ClassItem::Digit(false))),
            'D' => Ok(perl(ClassItem::Digit(true))),
            'w' => Ok(perl(ClassItem::Word(false))),
            'W' => Ok(perl(ClassItem::Word(true))),
            's' => Ok(perl(ClassItem::Space(false))),
            'S' => Ok(perl(ClassItem::Space(true))),
            'n' => Ok(Node::Char('\n')),
            't' => Ok(Node::Char('\t')),
            'r' => Ok(Node::Char('\r')),
            // Escaped metacharacters and punctuation match literally.
            c if !c.is_alphanumeric() => Ok(Node::Char(c)),
            c => Err(self.err(format!("unsupported escape '\\{c}'"))),
        }
    }

    fn class(&mut self) -> Result<Node, RexError> {
        let neg = if self.peek() == Some('^') {
            self.pos += 1;
            true
        } else {
            false
        };
        let mut items = Vec::new();
        loop {
            let c = self
                .bump()
                .ok_or_else(|| self.err("unclosed character class"))?;
            match c {
                ']' => {
                    // `]` as the very first item would be a literal in POSIX;
                    // keep it simple and reject the empty class instead.
                    if items.is_empty() {
                        return Err(self.err("empty character class"));
                    }
                    break;
                }
                '\\' => {
                    let e = self
                        .bump()
                        .ok_or_else(|| self.err("trailing backslash in class"))?;
                    let item = match e {
                        'd' => ClassItem::Digit(false),
                        'D' => ClassItem::Digit(true),
                        'w' => ClassItem::Word(false),
                        'W' => ClassItem::Word(true),
                        's' => ClassItem::Space(false),
                        'S' => ClassItem::Space(true),
                        'n' => ClassItem::Single('\n'),
                        't' => ClassItem::Single('\t'),
                        'r' => ClassItem::Single('\r'),
                        e if !e.is_alphanumeric() => ClassItem::Single(e),
                        e => return Err(self.err(format!("unsupported escape '\\{e}' in class"))),
                    };
                    items.push(item);
                }
                lo => {
                    // Possible range `a-z` (a trailing `-` is a literal).
                    if self.peek() == Some('-')
                        && self.chars.get(self.pos + 1).is_some_and(|&c| c != ']')
                    {
                        self.pos += 1; // consume '-'
                        let hi = self.bump().unwrap();
                        if hi == '\\' {
                            // `[0-\d]` and friends: reject rather than treat
                            // the backslash as a literal bound.
                            return Err(
                                self.err("escape sequences cannot bound a class range")
                            );
                        }
                        if hi < lo {
                            return Err(self.err(format!("invalid range {lo}-{hi}")));
                        }
                        items.push(ClassItem::Range(lo, hi));
                    } else {
                        items.push(ClassItem::Single(lo));
                    }
                }
            }
        }
        Ok(Node::Class { neg, items })
    }
}

fn class_item_matches(item: &ClassItem, c: char) -> bool {
    match item {
        ClassItem::Single(x) => c == *x,
        ClassItem::Range(lo, hi) => (*lo..=*hi).contains(&c),
        ClassItem::Digit(neg) => c.is_ascii_digit() != *neg,
        ClassItem::Word(neg) => (c.is_alphanumeric() || c == '_') != *neg,
        ClassItem::Space(neg) => c.is_whitespace() != *neg,
    }
}

fn atom_matches(node: &Node, c: char) -> bool {
    match node {
        Node::Char(x) => c == *x,
        Node::Any => true,
        Node::Class { neg, items } => items.iter().any(|i| class_item_matches(i, c)) != *neg,
        _ => false,
    }
}

/// Continuation stack for backtracking through groups.
enum Cont<'a> {
    Done,
    Nodes {
        nodes: &'a [Node],
        i: usize,
        next: &'a Cont<'a>,
    },
}

fn run_cont(cont: &Cont, text: &[char], pos: usize) -> bool {
    match cont {
        Cont::Done => true,
        Cont::Nodes { nodes, i, next } => match_nodes(nodes, *i, text, pos, next),
    }
}

fn match_nodes(nodes: &[Node], i: usize, text: &[char], pos: usize, cont: &Cont) -> bool {
    let Some(node) = nodes.get(i) else {
        return run_cont(cont, text, pos);
    };
    match node {
        Node::Char(_) | Node::Any | Node::Class { .. } => {
            pos < text.len()
                && atom_matches(node, text[pos])
                && match_nodes(nodes, i + 1, text, pos + 1, cont)
        }
        Node::Start => pos == 0 && match_nodes(nodes, i + 1, text, pos, cont),
        Node::End => pos == text.len() && match_nodes(nodes, i + 1, text, pos, cont),
        Node::Group(alts) => {
            let after = Cont::Nodes {
                nodes,
                i: i + 1,
                next: cont,
            };
            alts.iter()
                .any(|alt| match_nodes(alt, 0, text, pos, &after))
        }
        Node::Repeat { atom, min, max } => {
            // Greedy: consume as many as possible, then backtrack to `min`.
            let limit = max.unwrap_or(usize::MAX);
            let mut count = 0usize;
            while count < limit
                && pos + count < text.len()
                && atom_matches(atom, text[pos + count])
            {
                count += 1;
            }
            if count < *min {
                return false;
            }
            let mut c = count;
            loop {
                if match_nodes(nodes, i + 1, text, pos + c, cont) {
                    return true;
                }
                if c == *min {
                    return false;
                }
                c -= 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(pat: &str, text: &str) -> bool {
        Regex::new(pat).unwrap().is_match(text)
    }

    #[test]
    fn literals_and_search_semantics() {
        assert!(m("fc", "encoder.fc1"));
        assert!(m("fc1", "encoder.fc1"));
        assert!(!m("fc2", "encoder.fc1"));
    }

    #[test]
    fn anchors() {
        assert!(m("^encoder", "encoder.fc1"));
        assert!(!m("^fc1", "encoder.fc1"));
        assert!(m("fc1$", "encoder.fc1"));
        assert!(!m("encoder$", "encoder.fc1"));
        assert!(m("^abc$", "abc"));
        assert!(!m("^abc$", "xabc"));
    }

    #[test]
    fn perl_classes_and_quantifiers() {
        assert!(m(r"fc\d$", "encoder.fc1"));
        assert!(!m(r"fc\d$", "encoder.fc"));
        assert!(m(r"layer\d+\.fc", "encoder.layer12.fc"));
        assert!(!m(r"layer\d+\.fc", "encoder.layer.fc"));
        assert!(m(r"^encoder\.layer\d+\.fc$", "encoder.layer0.fc"));
        assert!(!m(r"^encoder\.layer\d+\.fc$", "encoder.layer0.fc.bias"));
        assert!(m(r"\w+", "abc_123"));
        assert!(m(r"a\s?b", "ab"));
        assert!(m(r"a\s?b", "a b"));
        assert!(m(r"ab*c", "ac"));
        assert!(m(r"ab*c", "abbbc"));
    }

    #[test]
    fn escaped_dot_vs_any() {
        assert!(m(r"a\.b", "a.b"));
        assert!(!m(r"a\.b", "axb"));
        assert!(m("a.b", "axb"));
    }

    #[test]
    fn alternation_groups() {
        let re = Regex::new(r"^encoder\.(conv|attn)$").unwrap();
        assert!(re.is_match("encoder.conv"));
        assert!(re.is_match("encoder.attn"));
        assert!(!re.is_match("encoder.fc1"));
        assert!(!re.is_match("encoder.convX"));
        assert!(m("(a|b|c)x", "bx"));
        assert!(!m("(a|b|c)x", "dx"));
    }

    #[test]
    fn char_classes() {
        assert!(m("[a-z]+[0-9]$", "fc1"));
        assert!(m("[^0-9]$", "fcx"));
        assert!(!m("^[^0-9]+$", "fc1"));
        assert!(m(r"[\d_-]+$", "12_-3"));
    }

    #[test]
    fn backtracking_repeat() {
        // Greedy + must give back characters for the suffix to match.
        assert!(m(r"^a+ab$", "aaab"));
        assert!(m(r"^.*fc$", "encoder.fc"));
        assert!(m(r"^\d*1$", "11"));
    }

    #[test]
    fn compile_errors() {
        assert!(Regex::new("(ab)+").is_err(), "quantified group unsupported");
        assert!(Regex::new("a{2,3}").is_err());
        assert!(Regex::new("[abc").is_err());
        assert!(Regex::new("(ab").is_err());
        assert!(Regex::new("*a").is_err());
        assert!(Regex::new(r"\q").is_err());
        assert!(Regex::new("a)b").is_err());
        assert!(Regex::new(r"[0-\d]").is_err(), "escape as range bound");
    }

    #[test]
    fn empty_pattern_matches_everything() {
        assert!(m("", ""));
        assert!(m("", "anything"));
    }
}
