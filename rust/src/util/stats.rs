//! Shared serving-statistics primitives: batch-occupancy and latency
//! histograms.
//!
//! These started life inside `coordinator::batcher` (occupancy) and the
//! coordinator metrics (latency aggregates); the [`crate::serve`]
//! subsystem needs the same shapes per tier, so the reusable pieces live
//! here and both callers build on them instead of duplicating the
//! counters. Everything is plain data — callers wrap in a `Mutex` (the
//! same interior-mutability pattern `CoordinatorMetrics` uses).

use std::time::Duration;

/// Histogram over batch occupancy: how many executed batches carried
/// 1, 2, …, capacity live rows. The mean occupancy is the ×-speedup a
/// dynamic batcher actually realizes over one-request-per-execution.
#[derive(Clone, Debug, Default)]
pub struct OccupancyHist {
    batches: u64,
    requests: u64,
    /// Index = rows used − 1.
    buckets: Vec<u64>,
}

impl OccupancyHist {
    /// Record one executed batch with `used` live rows out of `capacity`.
    /// `used` must be in `1..=capacity`.
    pub fn record(&mut self, used: usize, capacity: usize) {
        assert!(
            (1..=capacity).contains(&used),
            "occupancy {used}/{capacity}"
        );
        self.batches += 1;
        self.requests += used as u64;
        if self.buckets.len() < capacity {
            self.buckets.resize(capacity, 0);
        }
        self.buckets[used - 1] += 1;
    }

    /// Batches executed.
    pub fn batches(&self) -> u64 {
        self.batches
    }

    /// Live rows summed over all batches.
    pub fn requests(&self) -> u64 {
        self.requests
    }

    /// Mean live rows per executed batch (0 before the first batch).
    pub fn mean(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// The raw buckets (index = rows used − 1), sized to the largest
    /// capacity seen.
    pub fn buckets(&self) -> &[u64] {
        &self.buckets
    }

    /// Fold `other`'s batches into `self` — aggregating occupancy across
    /// several queues (e.g. every `coordinator` batcher of a process)
    /// without re-recording. Bucket vectors of different capacities
    /// align on index (rows used − 1), so the merged histogram is
    /// exactly what one shared histogram would have recorded.
    pub fn merge(&mut self, other: &OccupancyHist) {
        self.batches += other.batches;
        self.requests += other.requests;
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (b, &o) in self.buckets.iter_mut().zip(&other.buckets) {
            *b += o;
        }
    }
}

/// Bucket count of [`DurationHist`]: values 0–7 ns exact, then 4
/// sub-buckets per power of two up to `u64::MAX` ns.
const DURATION_BUCKETS: usize = 8 + 61 * 4;

/// Log-bucketed latency histogram with ~19 % bucket resolution
/// (4 sub-buckets per octave): O(1) record, O(buckets) quantiles, fixed
/// memory — the shape a long-lived serving process needs (storing raw
/// samples would grow without bound).
#[derive(Clone, Debug)]
pub struct DurationHist {
    count: u64,
    total: Duration,
    max: Duration,
    buckets: Box<[u64; DURATION_BUCKETS]>,
}

impl Default for DurationHist {
    fn default() -> Self {
        DurationHist {
            count: 0,
            total: Duration::ZERO,
            max: Duration::ZERO,
            buckets: Box::new([0; DURATION_BUCKETS]),
        }
    }
}

/// Bucket index for a nanosecond value: exact below 8, then
/// `(exponent, top-2 fraction bits)`.
fn bucket_of(ns: u64) -> usize {
    if ns < 8 {
        return ns as usize;
    }
    let e = 63 - ns.leading_zeros() as usize; // floor(log2), ≥ 3
    let frac = ((ns >> (e - 2)) & 3) as usize;
    8 + (e - 3) * 4 + frac
}

/// Lower edge (in ns) of bucket `idx` — what quantiles report.
fn bucket_floor(idx: usize) -> u64 {
    if idx < 8 {
        return idx as u64;
    }
    let e = 3 + (idx - 8) / 4;
    let frac = ((idx - 8) % 4) as u64;
    (1u64 << e) + frac * (1u64 << (e - 2))
}

impl DurationHist {
    /// Record one sample.
    pub fn record(&mut self, d: Duration) {
        self.count += 1;
        self.total += d;
        self.max = self.max.max(d);
        let ns = u64::try_from(d.as_nanos()).unwrap_or(u64::MAX);
        self.buckets[bucket_of(ns)] += 1;
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean over all samples (exact — tracked outside the buckets).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / u32::try_from(self.count).unwrap_or(u32::MAX)
        }
    }

    /// Largest sample (exact).
    pub fn max(&self) -> Duration {
        self.max
    }

    /// Approximate quantile `q ∈ [0, 1]`: the lower edge of the first
    /// bucket whose cumulative count reaches `q·count` (within the ~19 %
    /// bucket resolution). Zero before the first sample.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (idx, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                return Duration::from_nanos(bucket_floor(idx));
            }
        }
        self.max
    }

    /// Median (approximate; see [`DurationHist::quantile`]).
    pub fn p50(&self) -> Duration {
        self.quantile(0.50)
    }

    /// 99th percentile (approximate; see [`DurationHist::quantile`]).
    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }

    /// Fold `other`'s samples into `self`. Buckets are index-aligned
    /// (the layout is fixed), so the merged histogram reports exactly
    /// what one histogram fed both sample streams would — the building
    /// block of [`WindowedHist::snapshot`] and of aggregating per-tier
    /// latency into a server-wide view.
    pub fn merge(&mut self, other: &DurationHist) {
        self.count += other.count;
        self.total += other.total;
        self.max = self.max.max(other.max);
        for (b, &o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
    }
}

/// A sliding window over a [`DurationHist`]: a ring of `epochs` equal
/// sub-histograms, where [`WindowedHist::record`] writes into the
/// current epoch and [`WindowedHist::rotate`] retires the oldest. The
/// [`WindowedHist::snapshot`] merge therefore covers only the most
/// recent `epochs` rotations — the controller-facing view in which
/// stale history cannot steer admission decisions, unlike the
/// cumulative histograms the long-run metrics keep.
///
/// Rotation is explicit (no clock inside): callers decide the epoch
/// length — `serve::metrics` rotates on wall time, tests rotate
/// deterministically.
#[derive(Clone, Debug)]
pub struct WindowedHist {
    epochs: Vec<DurationHist>,
    /// Index of the epoch currently recording.
    head: usize,
}

impl WindowedHist {
    /// A window of `epochs` sub-histograms (at least 1).
    pub fn new(epochs: usize) -> Self {
        WindowedHist {
            epochs: vec![DurationHist::default(); epochs.max(1)],
            head: 0,
        }
    }

    /// Number of epochs in the ring.
    pub fn epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Record one sample into the current epoch.
    pub fn record(&mut self, d: Duration) {
        self.epochs[self.head].record(d);
    }

    /// Advance the window: the oldest epoch is cleared and becomes the
    /// new recording epoch. After `epochs()` consecutive rotations with
    /// no records, the snapshot is empty.
    pub fn rotate(&mut self) {
        self.head = (self.head + 1) % self.epochs.len();
        self.epochs[self.head] = DurationHist::default();
    }

    /// Merge every live epoch into one [`DurationHist`] — the windowed
    /// p50/p99/mean the admission controller reads.
    pub fn snapshot(&self) -> DurationHist {
        let mut out = DurationHist::default();
        for e in &self.epochs {
            out.merge(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_records_and_means() {
        let mut h = OccupancyHist::default();
        assert_eq!(h.mean(), 0.0);
        h.record(1, 4);
        h.record(4, 4);
        h.record(4, 4);
        assert_eq!(h.batches(), 3);
        assert_eq!(h.requests(), 9);
        assert!((h.mean() - 3.0).abs() < 1e-12);
        assert_eq!(h.buckets(), &[1, 0, 0, 2]);
        // A larger capacity grows the bucket vector in place.
        h.record(6, 8);
        assert_eq!(h.buckets().len(), 8);
        assert_eq!(h.buckets()[5], 1);
    }

    #[test]
    #[should_panic]
    fn occupancy_rejects_zero_used() {
        OccupancyHist::default().record(0, 4);
    }

    #[test]
    fn duration_buckets_are_monotone_and_invertible() {
        // Probe values across every exponent (plus sub-bucket offsets and
        // edge cases), in ascending ns order: bucket indices must be
        // non-decreasing and each bucket's floor must not exceed its
        // members.
        let mut vals: Vec<u64> = vec![1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 1_000_000, u64::MAX];
        for e in 0..60u32 {
            for off in [0u64, 1, 3] {
                vals.push((1u64 << e) + off * (1u64 << e.saturating_sub(2)));
            }
        }
        vals.sort_unstable();
        let mut prev = 0;
        for &ns in &vals {
            let idx = bucket_of(ns);
            assert!(idx >= prev, "bucket order at {ns}");
            assert!(idx < DURATION_BUCKETS);
            // The floor of a value's bucket never exceeds the value.
            assert!(bucket_floor(idx) <= ns, "floor({idx}) vs {ns}");
            prev = idx;
        }
    }

    #[test]
    fn occupancy_merge_matches_shared_recording() {
        // Two queues' histograms merged == one histogram fed both streams.
        let mut a = OccupancyHist::default();
        let mut b = OccupancyHist::default();
        let mut both = OccupancyHist::default();
        for (used, cap) in [(1usize, 4usize), (4, 4), (2, 4)] {
            a.record(used, cap);
            both.record(used, cap);
        }
        // b saw a larger capacity: merge must grow a's buckets.
        for (used, cap) in [(6usize, 8usize), (8, 8)] {
            b.record(used, cap);
            both.record(used, cap);
        }
        a.merge(&b);
        assert_eq!(a.batches(), both.batches());
        assert_eq!(a.requests(), both.requests());
        assert_eq!(a.buckets(), both.buckets());
        // Merging an empty histogram is the identity.
        let before = a.buckets().to_vec();
        a.merge(&OccupancyHist::default());
        assert_eq!(a.buckets(), &before[..]);
    }

    #[test]
    fn duration_merge_matches_shared_recording() {
        let mut a = DurationHist::default();
        let mut b = DurationHist::default();
        let mut both = DurationHist::default();
        for ms in [1u64, 3, 7] {
            a.record(Duration::from_millis(ms));
            both.record(Duration::from_millis(ms));
        }
        for ms in [2u64, 50] {
            b.record(Duration::from_millis(ms));
            both.record(Duration::from_millis(ms));
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.mean(), both.mean());
        assert_eq!(a.max(), both.max());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), both.quantile(q), "q={q}");
        }
    }

    #[test]
    fn windowed_hist_forgets_old_epochs() {
        let mut w = WindowedHist::new(3);
        assert_eq!(w.epochs(), 3);
        // Epoch 0: slow samples.
        w.record(Duration::from_millis(100));
        w.record(Duration::from_millis(100));
        assert_eq!(w.snapshot().count(), 2);
        assert!(w.snapshot().p50() >= Duration::from_millis(80));
        // Two newer epochs of fast samples: the slow epoch still rides
        // the window...
        for _ in 0..2 {
            w.rotate();
            for _ in 0..4 {
                w.record(Duration::from_millis(1));
            }
        }
        assert_eq!(w.snapshot().count(), 10);
        assert!(w.snapshot().max() == Duration::from_millis(100));
        // ...until one more rotation retires it: the stale history is
        // gone and the snapshot reflects only recent samples.
        w.rotate();
        let snap = w.snapshot();
        assert_eq!(snap.count(), 8);
        assert!(snap.max() <= Duration::from_millis(1));
        // A full ring of empty rotations drains the window entirely.
        for _ in 0..3 {
            w.rotate();
        }
        assert_eq!(w.snapshot().count(), 0);
        assert_eq!(w.snapshot().p99(), Duration::ZERO);
        // Degenerate: a zero-epoch request still yields a usable window.
        let mut w1 = WindowedHist::new(0);
        assert_eq!(w1.epochs(), 1);
        w1.record(Duration::from_millis(2));
        assert_eq!(w1.snapshot().count(), 1);
        w1.rotate();
        assert_eq!(w1.snapshot().count(), 0);
    }

    #[test]
    fn duration_quantiles_order_and_bound() {
        let mut h = DurationHist::default();
        assert_eq!(h.p50(), Duration::ZERO);
        for ms in [1u64, 2, 3, 4, 100] {
            h.record(Duration::from_millis(ms));
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.max(), Duration::from_millis(100));
        let (p50, p99) = (h.p50(), h.p99());
        assert!(p50 <= p99, "{p50:?} vs {p99:?}");
        // p50 lands in the bucket of the 3 ms sample: within 19 % below.
        assert!(p50 >= Duration::from_micros(2400) && p50 <= Duration::from_millis(3));
        // p99 lands in the 100 ms bucket.
        assert!(p99 >= Duration::from_millis(80) && p99 <= Duration::from_millis(100));
        assert!(h.mean() >= Duration::from_millis(22));
    }
}
