//! Std-only CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`).
//!
//! Used by checkpoint v3 ([`crate::train::checkpoint`]) to checksum every
//! tensor record and the whole file. Table-driven, one 1 KiB table built at
//! first use; no external dependencies.
//!
//! The algorithm is the ubiquitous reflected CRC-32 (zlib/PNG/Ethernet):
//! initial value `0xFFFF_FFFF`, process bytes LSB-first through the table,
//! final XOR with `0xFFFF_FFFF`. `crc32(b"123456789")` is the standard check
//! value `0xCBF4_3926`.

use std::sync::OnceLock;

/// Lazily built 256-entry lookup table for the reflected polynomial.
fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, slot) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *slot = c;
        }
        t
    })
}

/// Incremental CRC-32 hasher.
///
/// ```
/// use panther::util::crc::Crc32;
/// let mut h = Crc32::new();
/// h.update(b"1234");
/// h.update(b"56789");
/// assert_eq!(h.finish(), 0xCBF4_3926);
/// ```
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Fresh hasher (initial state `0xFFFF_FFFF`).
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in bytes {
            c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Final checksum value (does not consume; callers may keep updating,
    /// though the usual pattern is update-then-finish once).
    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut h = Crc32::new();
    h.update(bytes);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard check value for the IEEE reflected CRC-32.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
        assert_eq!(crc32(b"abc"), 0x3524_41C2);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let whole = crc32(&data);
        for split in [0, 1, 7, 4096, data.len()] {
            let mut h = Crc32::new();
            h.update(&data[..split]);
            h.update(&data[split..]);
            assert_eq!(h.finish(), whole, "split at {split}");
        }
    }

    #[test]
    fn detects_single_bit_flips() {
        let data = b"panther checkpoint record".to_vec();
        let clean = crc32(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                let mut corrupt = data.clone();
                corrupt[i] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), clean, "byte {i} bit {bit}");
            }
        }
    }
}
