//! Hand-rolled property-based testing harness (proptest is unavailable
//! offline). Deterministic: cases are generated from a Philox stream seeded
//! by the test name, so failures reproduce exactly. On failure the harness
//! reports the case index and the generated inputs' debug rendering.
//!
//! ```ignore
//! prop_check("qr_orthogonal", 64, |g| {
//!     let m = g.usize(1..40);
//!     let n = g.usize(1..=m);
//!     let a = Mat::randn(m, n, g.rng());
//!     let (q, _r) = qr(&a);
//!     assert!(ortho_error(&q) < 1e-4);
//! });
//! ```

use crate::rng::{Philox, Rng, SplitMix64};

/// Input generator handed to each property case.
pub struct Gen {
    rng: Philox,
    trace: Vec<String>,
}

impl Gen {
    /// Uniform usize in `range` (supports `a..b` and `a..=b` via RangeBounds).
    pub fn usize(&mut self, range: impl std::ops::RangeBounds<usize>) -> usize {
        let lo = match range.start_bound() {
            std::ops::Bound::Included(&v) => v,
            std::ops::Bound::Excluded(&v) => v + 1,
            std::ops::Bound::Unbounded => 0,
        };
        let hi = match range.end_bound() {
            std::ops::Bound::Included(&v) => v + 1,
            std::ops::Bound::Excluded(&v) => v,
            std::ops::Bound::Unbounded => usize::MAX,
        };
        assert!(hi > lo, "empty range");
        let v = lo + self.rng.next_below((hi - lo) as u32) as usize;
        self.trace.push(format!("usize={v}"));
        v
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.next_f32() * (hi - lo);
        self.trace.push(format!("f32={v}"));
        v
    }

    /// Standard normal f32.
    pub fn normal(&mut self) -> f32 {
        let v = self.rng.next_normal();
        self.trace.push(format!("normal={v}"));
        v
    }

    /// Bernoulli(p).
    pub fn bool(&mut self, p: f64) -> bool {
        let v = self.rng.next_f64() < p;
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Pick one element from a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.next_below(xs.len() as u32) as usize;
        self.trace.push(format!("choose[{i}]"));
        &xs[i]
    }

    /// Direct access to the underlying RNG (for bulk generation).
    pub fn rng(&mut self) -> &mut Philox {
        &mut self.rng
    }
}

/// Run `cases` generated instances of property `f`. Panics (failing the
/// enclosing `#[test]`) with the case index and input trace on the first
/// failing case.
pub fn prop_check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut f: F) {
    // Seed from the property name so each property has its own stream but is
    // fully deterministic run-to-run.
    let seed = name
        .bytes()
        .fold(0xA5A5_5A5A_u64, |acc, b| SplitMix64::mix(acc ^ b as u64));
    for case in 0..cases {
        let mut g = Gen {
            rng: Philox::new(seed, case as u64),
            trace: Vec::new(),
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| panic.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case}/{cases}\n  inputs: [{}]\n  cause: {msg}",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut first: Vec<usize> = Vec::new();
        prop_check("det-test", 10, |g| {
            first.push(g.usize(0..1000));
        });
        let mut second: Vec<usize> = Vec::new();
        prop_check("det-test", 10, |g| {
            second.push(g.usize(0..1000));
        });
        assert_eq!(first, second);
    }

    #[test]
    fn ranges_respected() {
        prop_check("range-test", 200, |g| {
            let v = g.usize(3..=7);
            assert!((3..=7).contains(&v));
            let f = g.f32(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed at case")]
    fn failure_reports_case() {
        prop_check("failing", 50, |g| {
            let v = g.usize(0..100);
            assert!(v < 2, "too big: {v}");
        });
    }
}
