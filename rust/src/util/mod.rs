//! Self-contained utilities (this build environment has no network access
//! to crates.io, so JSON, CLI parsing, bench statistics, the thread pool,
//! and property-testing helpers are implemented here from `std` only).

pub mod bench;
pub mod cli;
pub mod crc;
pub mod events;
pub mod json;
pub mod log;
pub mod memtrack;
pub mod prop;
pub mod rex;
pub mod stats;
pub mod threadpool;

/// Poison-tolerant mutex lock: recover the guard when a panicking thread
/// poisoned the lock. For counters/histograms that stay structurally valid
/// regardless of where a panic landed, poisoning must not cascade into
/// panics on every later read (worker panics are already surfaced via
/// [`threadpool::ThreadPool::panic_count`]).
pub fn lock_ignore_poison<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Format a byte count human-readably (`1.5 GiB` style).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a duration in adaptive units (`1.23 ms` style).
pub fn human_duration(d: std::time::Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn duration_formatting() {
        use std::time::Duration;
        assert!(human_duration(Duration::from_nanos(100)).ends_with("ns"));
        assert!(human_duration(Duration::from_micros(100)).ends_with("µs"));
        assert!(human_duration(Duration::from_millis(100)).ends_with("ms"));
        assert!(human_duration(Duration::from_secs(100)).ends_with(" s"));
    }
}
