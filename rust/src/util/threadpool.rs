//! A small work-stealing-free thread pool (tokio is unavailable offline; the
//! coordinator's workloads are coarse-grained, so a shared-queue pool with
//! scoped parallel-for is sufficient and much simpler to reason about).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool with job counting, so callers can block until all
/// outstanding jobs are finished (`wait_idle`) — the pattern the trial
/// scheduler and the blocked GEMM both use.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

struct Shared {
    queue_rx: Mutex<mpsc::Receiver<Msg>>,
    pending: AtomicUsize,
    idle: Condvar,
    idle_lock: Mutex<()>,
    panics: AtomicUsize,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Shared {
            queue_rx: Mutex::new(rx),
            pending: AtomicUsize::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("panther-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx,
            shared,
            workers,
        }
    }

    /// Pool sized to the machine (#cpus, capped at 16).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    pub fn with_default_size() -> Self {
        Self::new(Self::default_size())
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_lock.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
        drop(guard);
    }

    /// Number of jobs that panicked since pool creation. Worker panics never
    /// kill the pool or poison caller-side locks — they are caught, counted
    /// here, and (for `parallel_for`) re-surfaced on the *calling* thread
    /// once all workers have finished.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Run `f(i)` for `i in 0..n` across the pool's **persistent workers**
    /// and wait. `f` must be `Sync` since multiple workers call it
    /// concurrently. This is the GEMM tile dispatch path, so per-call
    /// overhead matters: helper jobs run on the long-lived queue workers
    /// (no thread spawns per call — the former implementation spawned up
    /// to `num_workers` scoped threads per invocation), and the calling
    /// thread participates in the index loop itself, so the call makes
    /// forward progress even when every queue worker is busy.
    ///
    /// Contract: `f` must be **leaf work** — it must not call
    /// `parallel_for` on this same pool. (A nested call still drains its
    /// own indices via caller participation, but if every worker blocked
    /// waiting on queued helpers simultaneously, the queue would starve.
    /// All in-crate callers are plain tile loops.)
    ///
    /// Borrowed closures cross the `'static` bound of the job queue
    /// through a raw pointer to a stack-owned dispatch context; this is
    /// sound because the caller blocks until every helper job has signaled
    /// completion before the context drops.
    ///
    /// Panics in `f` are caught per index, counted in the pool's panic
    /// counter, and re-raised as a single panic on the calling thread after
    /// every index has been attempted — so sibling work completes, no worker
    /// dies mid-queue, and no mutex held by the caller is poisoned from a
    /// foreign thread.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        // Helper jobs beyond the caller's own lane.
        let helpers = self.num_workers().min(n).saturating_sub(1);
        let ctx = ForCtx {
            f: &f,
            n,
            next: AtomicUsize::new(0),
            panics: AtomicUsize::new(0),
            first_cause: Mutex::new(None),
            remaining: Mutex::new(helpers),
            done: Condvar::new(),
        };
        if helpers > 0 {
            let addr = &ctx as *const ForCtx as usize;
            for _ in 0..helpers {
                self.execute(move || {
                    // SAFETY: the caller below blocks until `remaining`
                    // reaches zero, so the context (and the borrowed
                    // closure inside it) outlives every dereference — the
                    // 'static in the cast is lifetime erasure, upheld by
                    // that blocking; `finish` touches nothing after its
                    // decrement.
                    let ctx = unsafe { &*(addr as *const ForCtx<'static>) };
                    ctx.work();
                    ctx.finish();
                });
            }
        }
        ctx.work();
        if helpers > 0 {
            let mut rem = crate::util::lock_ignore_poison(&ctx.remaining);
            while *rem > 0 {
                rem = ctx
                    .done
                    .wait(rem)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        }
        let new_panics = ctx.panics.load(Ordering::SeqCst);
        if new_panics > 0 {
            let total = self.shared.panics.fetch_add(new_panics, Ordering::SeqCst) + new_panics;
            let cause = crate::util::lock_ignore_poison(&ctx.first_cause)
                .take()
                .unwrap_or_default();
            panic!(
                "parallel_for: {new_panics} of {n} jobs panicked \
                 (pool panic_count now {total}); first cause: {cause}"
            );
        }
    }
}

/// Stack-owned dispatch state shared between a `parallel_for` caller and
/// its helper jobs on the persistent workers (see the safety note there).
struct ForCtx<'a> {
    f: &'a (dyn Fn(usize) + Sync),
    n: usize,
    next: AtomicUsize,
    panics: AtomicUsize,
    /// First panic payload, so the re-raised panic names the actual cause.
    first_cause: Mutex<Option<String>>,
    remaining: Mutex<usize>,
    done: Condvar,
}

impl ForCtx<'_> {
    /// Drain indices from the shared counter until the range is exhausted,
    /// catching (and recording) panics per index.
    fn work(&self) {
        loop {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| (self.f)(i))) {
                let msg = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                crate::util::lock_ignore_poison(&self.first_cause).get_or_insert(msg);
                self.panics.fetch_add(1, Ordering::SeqCst);
            }
        }
    }

    /// Helper-job epilogue: signal the caller. Must be the job's last
    /// touch of `self` (the caller may free the context right after).
    fn finish(&self) {
        let mut rem = crate::util::lock_ignore_poison(&self.remaining);
        *rem -= 1;
        if *rem == 0 {
            self.done.notify_all();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let msg = {
            let rx = shared.queue_rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                let res = catch_unwind(AssertUnwindSafe(job));
                if res.is_err() {
                    shared.panics.fetch_add(1, Ordering::SeqCst);
                }
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle_lock.lock().unwrap();
                    shared.idle.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(8);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn panic_is_counted_not_fatal() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_for_concurrent_callers_share_the_workers() {
        // Several threads dispatching onto one pool at once: every index of
        // every call must run exactly once (each call has its own dispatch
        // context; the queue interleaves their helper jobs).
        let pool = Arc::new(ThreadPool::new(4));
        let total = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = Arc::clone(&pool);
                let t = Arc::clone(&total);
                thread::spawn(move || {
                    p.parallel_for(250, |_| {
                        t.fetch_add(1, Ordering::SeqCst);
                    })
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn parallel_for_zero_items() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| unreachable!());
    }

    #[test]
    fn parallel_for_panic_surfaces_on_caller_with_count() {
        let pool = ThreadPool::new(4);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(100, |i| {
                if i == 13 || i == 77 {
                    panic!("job {i} failed");
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }));
        let err = result.expect_err("caller must observe the failure");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("2 of 100 jobs panicked"), "message: {msg}");
        assert!(msg.contains("failed"), "first cause missing: {msg}");
        assert_eq!(pool.panic_count(), 2);
        // Sibling jobs were not abandoned when one panicked.
        assert_eq!(done.load(Ordering::SeqCst), 98);
        // The pool is still usable afterwards.
        pool.parallel_for(10, |_| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 108);
    }
}
