//! A small work-stealing-free thread pool (tokio is unavailable offline; the
//! coordinator's workloads are coarse-grained, so a shared-queue pool with
//! scoped parallel-for is sufficient and much simpler to reason about).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool with job counting, so callers can block until all
/// outstanding jobs are finished (`wait_idle`) — the pattern the trial
/// scheduler and the blocked GEMM both use.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

struct Shared {
    queue_rx: Mutex<mpsc::Receiver<Msg>>,
    pending: AtomicUsize,
    idle: Condvar,
    idle_lock: Mutex<()>,
    panics: AtomicUsize,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Shared {
            queue_rx: Mutex::new(rx),
            pending: AtomicUsize::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("panther-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx,
            shared,
            workers,
        }
    }

    /// Pool sized to the machine (#cpus, capped at 16).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    pub fn with_default_size() -> Self {
        Self::new(Self::default_size())
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_lock.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
        drop(guard);
    }

    /// Number of jobs that panicked since pool creation. Worker panics never
    /// kill the pool or poison caller-side locks — they are caught, counted
    /// here, and (for `parallel_for`) re-surfaced on the *calling* thread
    /// once all workers have finished.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Run `f(i)` for `i in 0..n` across scoped worker threads and wait.
    /// `f` must be `Sync` since multiple workers call it concurrently.
    /// (Scoped threads rather than the shared queue: jobs may borrow `f`
    /// and local data, which `execute`'s `'static` bound cannot express.)
    ///
    /// Panics in `f` are caught on the worker, counted in the pool's panic
    /// counter, and re-raised as a single panic on the calling thread after
    /// every index has been attempted — so sibling work completes, no worker
    /// dies mid-queue, and no mutex held by the caller is poisoned from a
    /// foreign thread.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        // Keep the first panic's payload so the re-raised panic names the
        // actual cause, not just a count.
        let first_cause: Mutex<Option<String>> = Mutex::new(None);
        let run_caught = |i: usize| -> bool {
            match catch_unwind(AssertUnwindSafe(|| f(i))) {
                Ok(()) => false,
                Err(payload) => {
                    let msg = payload
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "<non-string panic>".to_string());
                    let mut slot = first_cause
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    slot.get_or_insert(msg);
                    true
                }
            }
        };
        let workers = self.num_workers().min(n);
        let mut new_panics = 0usize;
        if workers <= 1 {
            for i in 0..n {
                if run_caught(i) {
                    new_panics += 1;
                }
            }
        } else {
            let next = AtomicUsize::new(0);
            let panicked = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        if run_caught(i) {
                            panicked.fetch_add(1, Ordering::SeqCst);
                        }
                    });
                }
            });
            new_panics = panicked.load(Ordering::SeqCst);
        }
        if new_panics > 0 {
            let total = self.shared.panics.fetch_add(new_panics, Ordering::SeqCst) + new_panics;
            let cause = first_cause
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .take()
                .unwrap_or_default();
            panic!(
                "parallel_for: {new_panics} of {n} jobs panicked \
                 (pool panic_count now {total}); first cause: {cause}"
            );
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let msg = {
            let rx = shared.queue_rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                let res = catch_unwind(AssertUnwindSafe(job));
                if res.is_err() {
                    shared.panics.fetch_add(1, Ordering::SeqCst);
                }
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle_lock.lock().unwrap();
                    shared.idle.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(8);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn panic_is_counted_not_fatal() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_for_zero_items() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| unreachable!());
    }

    #[test]
    fn parallel_for_panic_surfaces_on_caller_with_count() {
        let pool = ThreadPool::new(4);
        let done = AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(100, |i| {
                if i == 13 || i == 77 {
                    panic!("job {i} failed");
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }));
        let err = result.expect_err("caller must observe the failure");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("2 of 100 jobs panicked"), "message: {msg}");
        assert!(msg.contains("failed"), "first cause missing: {msg}");
        assert_eq!(pool.panic_count(), 2);
        // Sibling jobs were not abandoned when one panicked.
        assert_eq!(done.load(Ordering::SeqCst), 98);
        // The pool is still usable afterwards.
        pool.parallel_for(10, |_| {
            done.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(done.load(Ordering::SeqCst), 108);
    }
}
