//! A small work-stealing-free thread pool (tokio is unavailable offline; the
//! coordinator's workloads are coarse-grained, so a shared-queue pool with
//! scoped parallel-for is sufficient and much simpler to reason about).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// Fixed-size thread pool with job counting, so callers can block until all
/// outstanding jobs are finished (`wait_idle`) — the pattern the trial
/// scheduler and the blocked GEMM both use.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<thread::JoinHandle<()>>,
}

struct Shared {
    queue_rx: Mutex<mpsc::Receiver<Msg>>,
    pending: AtomicUsize,
    idle: Condvar,
    idle_lock: Mutex<()>,
    panics: AtomicUsize,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let shared = Arc::new(Shared {
            queue_rx: Mutex::new(rx),
            pending: AtomicUsize::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..n)
            .map(|i| {
                let shared = Arc::clone(&shared);
                thread::Builder::new()
                    .name(format!("panther-worker-{i}"))
                    .spawn(move || worker_loop(shared))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx,
            shared,
            workers,
        }
    }

    /// Pool sized to the machine (#cpus, capped at 16).
    pub fn default_size() -> usize {
        thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    }

    pub fn with_default_size() -> Self {
        Self::new(Self::default_size())
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.pending.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_lock.lock().unwrap();
        while self.shared.pending.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle.wait(guard).unwrap();
        }
        drop(guard);
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panic_count(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }

    /// Run `f(i)` for `i in 0..n` across scoped worker threads and wait.
    /// `f` must be `Sync` since multiple workers call it concurrently.
    /// (Scoped threads rather than the shared queue: jobs may borrow `f`
    /// and local data, which `execute`'s `'static` bound cannot express.)
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        let workers = self.num_workers().min(n);
        if workers <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    f(i);
                });
            }
        });
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let msg = {
            let rx = shared.queue_rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                let res = catch_unwind(AssertUnwindSafe(job));
                if res.is_err() {
                    shared.panics.fetch_add(1, Ordering::SeqCst);
                }
                if shared.pending.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = shared.idle_lock.lock().unwrap();
                    shared.idle.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => break,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_for_covers_every_index_once() {
        let pool = ThreadPool::new(8);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        pool.parallel_for(1000, |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn panic_is_counted_not_fatal() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.execute(|| {});
        pool.wait_idle();
        assert_eq!(pool.panic_count(), 1);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn parallel_for_zero_items() {
        let pool = ThreadPool::new(2);
        pool.parallel_for(0, |_| unreachable!());
    }
}
