//! Minimal JSON value model, parser, and writer.
//!
//! Used for the artifact `manifest.json`, tuner study persistence, and
//! experiment reports. Supports the full JSON grammar; numbers are `f64`
//! (i.e. 53-bit integers round-trip, which is ample here).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-object — builder use only).
    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact serialization.
    #[allow(clippy::inherent_to_string_shadow_display)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    // JSON has no Inf/NaN; emit null (matches serde_json's lossy mode).
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Json {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(c.ok_or_else(|| self.err("bad unicode escape"))?);
                            continue; // hex4 advanced pos already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let end = (start + len).min(self.bytes.len());
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("short unicode escape"));
        }
        let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a":1,"b":[true,null,"x\ny"],"c":{"d":-2.5e3}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
    }

    #[test]
    fn parses_nested_arrays() {
        let v = Json::parse("[[1,2],[3,[4]]]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[1].as_arr().unwrap()[0].as_f64(), Some(4.0));
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn builder_and_pretty() {
        let mut o = Json::obj();
        o.set("name", "panther").set("version", 1i64).set(
            "tags",
            vec!["randnla".to_string(), "sketching".to_string()],
        );
        let pretty = o.to_pretty();
        assert!(pretty.contains("\"name\": \"panther\""));
        let back = Json::parse(&pretty).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn integers_exact() {
        let v = Json::parse("9007199254740991").unwrap();
        assert_eq!(v.to_string(), "9007199254740991");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap().to_string(), "{}");
        assert_eq!(Json::parse("[]").unwrap().to_string(), "[]");
    }
}
