//! Counter-based random number generation.
//!
//! Sketching operators must be (a) cheap, (b) reproducible, and (c) safely
//! parallelizable — a worker sketching column block `j` must be able to
//! generate exactly the entries it needs without coordinating with other
//! workers. Counter-based generators (Salmon et al., "Parallel Random
//! Numbers: As Easy as 1, 2, 3") give all three; we implement
//! **Philox-4x32-10**, the same family used by JAX's `threefry`/`philox`
//! PRNGs, plus a tiny SplitMix64 for seeding and cheap non-crypto use.

mod philox;
mod splitmix;

pub use philox::Philox;
pub use splitmix::SplitMix64;

/// A minimal uniform-random source. Implemented by both generators so
/// higher-level samplers ([`normal`], [`rademacher`], …) are generic.
pub trait Rng {
    /// Next 32 uniform random bits.
    fn next_u32(&mut self) -> u32;

    /// Next 64 uniform random bits.
    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of mantissa.
    fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of mantissa.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (n > 0) via Lemire-style rejection.
    fn next_below(&mut self, n: u32) -> u32 {
        assert!(n > 0, "next_below(0)");
        // Rejection sampling on the top of the range to avoid modulo bias.
        let zone = u32::MAX - (u32::MAX % n);
        loop {
            let v = self.next_u32();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box–Muller (cached spare is *not* kept: callers
    /// that need bulk normals should use [`fill_normal`]).
    fn next_normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 > 1e-10 {
                let u2 = self.next_f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// ±1 with equal probability.
    fn next_sign(&mut self) -> f32 {
        if self.next_u32() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }
}

/// Fill `out` with i.i.d. standard normals (pairwise Box–Muller, no waste).
pub fn fill_normal<R: Rng>(rng: &mut R, out: &mut [f32]) {
    let mut i = 0;
    while i + 1 < out.len() {
        let (a, b) = normal_pair(rng);
        out[i] = a;
        out[i + 1] = b;
        i += 2;
    }
    if i < out.len() {
        out[i] = rng.next_normal();
    }
}

/// Fill `out` with i.i.d. Rademacher (±1) entries.
pub fn fill_sign<R: Rng>(rng: &mut R, out: &mut [f32]) {
    // Use each u32 for 32 signs.
    let mut i = 0;
    while i < out.len() {
        let mut bits = rng.next_u32();
        let n = 32.min(out.len() - i);
        for _ in 0..n {
            out[i] = if bits & 1 == 0 { 1.0 } else { -1.0 };
            bits >>= 1;
            i += 1;
        }
    }
}

/// One Box–Muller pair.
fn normal_pair<R: Rng>(rng: &mut R) -> (f32, f32) {
    loop {
        let u1 = rng.next_f32();
        if u1 > 1e-10 {
            let u2 = rng.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            let t = 2.0 * std::f32::consts::PI * u2;
            return (r * t.cos(), r * t.sin());
        }
    }
}

/// Fisher–Yates shuffle of a slice.
pub fn shuffle<R: Rng, T>(rng: &mut R, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        let j = rng.next_below(i as u32 + 1) as usize;
        xs.swap(i, j);
    }
}

/// A random permutation of `0..n`.
pub fn permutation<R: Rng>(rng: &mut R, n: usize) -> Vec<usize> {
    let mut p: Vec<usize> = (0..n).collect();
    shuffle(rng, &mut p);
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn philox_reproducible() {
        let mut a = Philox::seeded(42);
        let mut b = Philox::seeded(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn philox_seed_sensitivity() {
        let mut a = Philox::seeded(1);
        let mut b = Philox::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "different seeds should decorrelate: {same}");
    }

    #[test]
    fn uniform_range() {
        let mut r = Philox::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let y = r.next_f64();
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn next_below_unbiased_small() {
        let mut r = Philox::seeded(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[r.next_below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).abs() < (expect as f64 * 0.1) as i64,
                "bucket count {c} too far from {expect}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Philox::seeded(11);
        let mut buf = vec![0f32; 200_000];
        fill_normal(&mut r, &mut buf);
        let mean: f64 = buf.iter().map(|&x| x as f64).sum::<f64>() / buf.len() as f64;
        let var: f64 =
            buf.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn sign_balance() {
        let mut r = Philox::seeded(13);
        let mut buf = vec![0f32; 100_000];
        fill_sign(&mut r, &mut buf);
        let pos = buf.iter().filter(|&&x| x == 1.0).count();
        assert!(buf.iter().all(|&x| x == 1.0 || x == -1.0));
        assert!((pos as i64 - 50_000).abs() < 2_000, "pos {pos}");
    }

    #[test]
    fn permutation_valid() {
        let mut r = Philox::seeded(17);
        let p = permutation(&mut r, 100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn stream_independence() {
        // Streams with different counter prefixes must not collide.
        let mut a = Philox::new(99, 0);
        let mut b = Philox::new(99, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }
}
