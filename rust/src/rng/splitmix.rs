//! SplitMix64 — tiny, fast, used for seeding and non-statistical choices.

use super::Rng;

/// SplitMix64 (Steele, Lea, Flood 2014). One 64-bit state word; passes
/// BigCrush. Used where a full Philox stream is overkill (hash mixing,
/// tie-breaking, seed derivation).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Stateless mix — good as a hash finalizer.
    #[inline]
    pub fn mix(x: u64) -> u64 {
        let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        (self.next() >> 32) as u32
    }

    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.next()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vector() {
        // Reference values from the public-domain splitmix64.c (seed 1234567).
        let mut s = SplitMix64::new(1234567);
        let v = s.next();
        assert_eq!(v, 6457827717110365317);
    }

    #[test]
    fn mix_is_stateless() {
        assert_eq!(SplitMix64::mix(42), SplitMix64::mix(42));
        assert_ne!(SplitMix64::mix(42), SplitMix64::mix(43));
    }
}
