//! Philox-4x32-10 counter-based PRNG (Salmon et al., SC'11).

use super::Rng;

const PHILOX_M0: u32 = 0xD251_1F53;
const PHILOX_M1: u32 = 0xCD9E_8D57;
const PHILOX_W0: u32 = 0x9E37_79B9; // golden ratio
const PHILOX_W1: u32 = 0xBB67_AE85; // sqrt(3) - 1

/// Philox-4x32-10: a counter-based generator. Each 128-bit counter value is
/// bijectively mapped to 128 random bits through 10 rounds of a cheap
/// multiply-xor network keyed by a 64-bit key. Identical `(key, stream)`
/// pairs always produce identical sequences, and distinct streams are
/// statistically independent — exactly what parallel sketching needs.
#[derive(Clone, Debug)]
pub struct Philox {
    key: [u32; 2],
    counter: [u32; 4],
    /// Buffered outputs from the last block.
    buf: [u32; 4],
    /// Next index into `buf`; 4 means "exhausted".
    idx: usize,
}

impl Philox {
    /// New generator with explicit `key` (seed) and `stream` id. Streams
    /// partition the counter space: stream `s` starts at counter
    /// `[0, 0, lo(s), hi(s)]`, giving 2^64 blocks per stream.
    pub fn new(key: u64, stream: u64) -> Self {
        Philox {
            key: [key as u32, (key >> 32) as u32],
            counter: [0, 0, stream as u32, (stream >> 32) as u32],
            buf: [0; 4],
            idx: 4,
        }
    }

    /// Convenience: stream 0.
    pub fn seeded(key: u64) -> Self {
        Self::new(key, 0)
    }

    /// Jump directly to block `block` within this stream (for random access).
    pub fn set_block(&mut self, block: u64) {
        self.counter[0] = block as u32;
        self.counter[1] = (block >> 32) as u32;
        self.idx = 4;
    }

    #[inline]
    fn round(ctr: [u32; 4], key: [u32; 2]) -> [u32; 4] {
        let lo0 = PHILOX_M0.wrapping_mul(ctr[0]);
        let hi0 = ((PHILOX_M0 as u64 * ctr[0] as u64) >> 32) as u32;
        let lo1 = PHILOX_M1.wrapping_mul(ctr[2]);
        let hi1 = ((PHILOX_M1 as u64 * ctr[2] as u64) >> 32) as u32;
        [
            hi1 ^ ctr[1] ^ key[0],
            lo1,
            hi0 ^ ctr[3] ^ key[1],
            lo0,
        ]
    }

    /// Run the 10-round block function on `counter`, refill `buf`.
    fn refill(&mut self) {
        let mut ctr = self.counter;
        let mut key = self.key;
        for _ in 0..10 {
            ctr = Self::round(ctr, key);
            key[0] = key[0].wrapping_add(PHILOX_W0);
            key[1] = key[1].wrapping_add(PHILOX_W1);
        }
        self.buf = ctr;
        self.idx = 0;
        // Increment the 64-bit block counter.
        let (c0, carry) = self.counter[0].overflowing_add(1);
        self.counter[0] = c0;
        if carry {
            self.counter[1] = self.counter[1].wrapping_add(1);
        }
    }
}

impl Rng for Philox {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        if self.idx >= 4 {
            self.refill();
        }
        let v = self.buf[self.idx];
        self.idx += 1;
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn block_function_bijective_on_sample() {
        // Distinct counters must map to distinct outputs (spot check).
        let mut seen = std::collections::HashSet::new();
        for s in 0..256u64 {
            let mut p = Philox::new(5, s);
            let v = (p.next_u64(), p.next_u64());
            assert!(seen.insert(v), "collision at stream {s}");
        }
    }

    #[test]
    fn set_block_random_access() {
        let mut a = Philox::seeded(9);
        // consume 3 blocks
        for _ in 0..12 {
            a.next_u32();
        }
        let direct: Vec<u32> = (0..4).map(|_| a.next_u32()).collect();
        let mut b = Philox::seeded(9);
        b.set_block(3);
        let jumped: Vec<u32> = (0..4).map(|_| b.next_u32()).collect();
        assert_eq!(direct, jumped);
    }

    #[test]
    fn counter_carry() {
        let mut p = Philox::seeded(1);
        p.counter[0] = u32::MAX;
        p.refill();
        assert_eq!(p.counter[0], 0);
        assert_eq!(p.counter[1], 1);
    }
}
