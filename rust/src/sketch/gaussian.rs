//! Dense Gaussian sketch: `S[i,j] ~ N(0, 1/d)`.

use super::Sketch;
use crate::linalg::{matmul, Mat};
use crate::rng::{fill_normal, Philox};

/// The classical JL sketch. O(d·m) storage when materialized but we generate
/// rows on the fly from a Philox stream keyed by `(seed, row)` — workers can
/// regenerate any block without communication.
pub struct GaussianSketch {
    m: usize,
    d: usize,
    seed: u64,
}

impl GaussianSketch {
    pub fn new(m: usize, d: usize, seed: u64) -> Self {
        assert!(d > 0 && m > 0);
        GaussianSketch { m, d, seed }
    }

    /// Generate row `i` of `S` (length m), scaled by 1/√d.
    fn row(&self, i: usize) -> Vec<f32> {
        let mut rng = Philox::new(self.seed, i as u64);
        let mut r = vec![0f32; self.m];
        fill_normal(&mut rng, &mut r);
        let scale = 1.0 / (self.d as f32).sqrt();
        for v in &mut r {
            *v *= scale;
        }
        r
    }
}

impl Sketch for GaussianSketch {
    fn input_dim(&self) -> usize {
        self.m
    }

    fn output_dim(&self) -> usize {
        self.d
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows(), self.m, "sketch input mismatch");
        // S·A via materialized S — the GEMM is the fast path and d is small.
        matmul(&self.to_dense(), a)
    }

    fn to_dense(&self) -> Mat {
        let mut s = Mat::zeros(self.d, self.m);
        for i in 0..self.d {
            s.row_mut(i).copy_from_slice(&self.row(i));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_reproducible_independent() {
        let s = GaussianSketch::new(100, 10, 9);
        assert_eq!(s.row(3), s.row(3));
        assert_ne!(s.row(3), s.row(4));
    }

    #[test]
    fn variance_scaling() {
        let s = GaussianSketch::new(4000, 64, 1);
        let r = s.row(0);
        let var: f64 = r.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / r.len() as f64;
        // Var = 1/d = 1/64.
        assert!((var - 1.0 / 64.0).abs() < 0.2 / 64.0, "var {var}");
    }
}
