//! CountSketch: one nonzero (±1) per input coordinate. The cheapest sketch
//! to apply — a single pass over the data — and the basis of tensor-sketch
//! convolution approximations [Kasiviswanathan et al. 2017].

use super::Sketch;
use crate::linalg::Mat;
use crate::rng::{Philox, Rng};

pub struct CountSketch {
    m: usize,
    d: usize,
    seed: u64,
}

impl CountSketch {
    pub fn new(m: usize, d: usize, seed: u64) -> Self {
        assert!(d > 0 && m > 0);
        CountSketch { m, d, seed }
    }

    /// Hash of coordinate `j`: (target row, sign).
    #[inline]
    fn hash(&self, j: usize) -> (usize, f32) {
        let mut rng = Philox::new(self.seed, j as u64);
        let row = rng.next_below(self.d as u32) as usize;
        (row, rng.next_sign())
    }
}

impl Sketch for CountSketch {
    fn input_dim(&self) -> usize {
        self.m
    }

    fn output_dim(&self) -> usize {
        self.d
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows(), self.m);
        let mut out = Mat::zeros(self.d, a.cols());
        for srow in 0..self.m {
            let (drow, sign) = self.hash(srow);
            let arow = a.row(srow);
            let orow = out.row_mut(drow);
            if sign > 0.0 {
                for (o, &v) in orow.iter_mut().zip(arow) {
                    *o += v;
                }
            } else {
                for (o, &v) in orow.iter_mut().zip(arow) {
                    *o -= v;
                }
            }
        }
        out
    }

    fn to_dense(&self) -> Mat {
        let mut s = Mat::zeros(self.d, self.m);
        for j in 0..self.m {
            let (i, sg) = self.hash(j);
            s.set(i, j, sg);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_nonzero_per_column() {
        let s = CountSketch::new(40, 8, 2).to_dense();
        for j in 0..40 {
            let nnz = (0..8).filter(|&i| s.get(i, j) != 0.0).count();
            assert_eq!(nnz, 1);
        }
    }

    #[test]
    fn entries_are_signs() {
        let s = CountSketch::new(40, 8, 2).to_dense();
        for &v in s.data() {
            assert!(v == 0.0 || v == 1.0 || v == -1.0);
        }
    }

    #[test]
    fn apply_linear_in_input() {
        let cs = CountSketch::new(20, 5, 7);
        let a = Mat::randn(20, 3, &mut Philox::seeded(1));
        let b = Mat::randn(20, 3, &mut Philox::seeded(2));
        let sum = cs.apply(&a.add(&b));
        let parts = cs.apply(&a).add(&cs.apply(&b));
        assert!(crate::linalg::rel_error(&sum, &parts) < 1e-5);
    }
}
