//! Subsampled Randomized Hadamard Transform: `S = √(mpad/d)·P·H·D` where `D` is
//! a random sign diagonal, `H` the (normalized) Walsh–Hadamard transform,
//! and `P` samples `d` rows. Applies in O(m log m) per column via the fast
//! WHT; the Hadamard mixing makes row sampling safe for arbitrary inputs.

use super::Sketch;
use crate::linalg::Mat;
use crate::rng::{fill_sign, Philox, Rng};

pub struct SrhtSketch {
    m: usize,
    /// m rounded up to a power of two (the FWHT size).
    mpad: usize,
    d: usize,
    signs: Vec<f32>,
    rows: Vec<usize>,
}

impl SrhtSketch {
    pub fn new(m: usize, d: usize, seed: u64) -> Self {
        assert!(d > 0 && m > 0);
        let mpad = m.next_power_of_two();
        // A sketch cannot sample more distinct transform rows than the padded
        // transform has. Silently shrinking `d` here used to hand callers an
        // operator with a different output_dim than requested — fail loudly
        // instead.
        assert!(
            d <= mpad,
            "SrhtSketch: sketch size d={d} exceeds padded input size {mpad} \
             (m={m} rounds up to {mpad}); choose d <= {mpad}"
        );
        let mut rng = Philox::new(seed, 0);
        let mut signs = vec![0f32; m];
        fill_sign(&mut rng, &mut signs);
        // Sample d distinct rows of the padded transform.
        let mut rows = Vec::with_capacity(d);
        let mut chosen = std::collections::HashSet::with_capacity(d);
        let mut row_rng = Philox::new(seed, 1);
        while rows.len() < d {
            let r = row_rng.next_below(mpad as u32) as usize;
            if chosen.insert(r) {
                rows.push(r);
            }
        }
        SrhtSketch {
            m,
            mpad,
            d,
            signs,
            rows,
        }
    }

    /// In-place fast Walsh–Hadamard transform (unnormalized).
    fn fwht(buf: &mut [f64]) {
        let n = buf.len();
        let mut h = 1;
        while h < n {
            for i in (0..n).step_by(h * 2) {
                for j in i..i + h {
                    let x = buf[j];
                    let y = buf[j + h];
                    buf[j] = x + y;
                    buf[j + h] = x - y;
                }
            }
            h *= 2;
        }
    }
}

impl Sketch for SrhtSketch {
    fn input_dim(&self) -> usize {
        self.m
    }

    fn output_dim(&self) -> usize {
        self.d
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows(), self.m);
        let n = a.cols();
        let mut out = Mat::zeros(self.d, n);
        // Scaling: `fwht` below is the *unnormalized* transform (entries ±1,
        // i.e. √mpad times the orthonormal H), and the operator is
        // S = √(mpad/d)·P·H·D. Folding the normalizations together:
        //   √(mpad/d) · (1/√mpad) · fwht = (1/√d) · fwht,
        // so a single 1/√d factor on the sampled rows makes E‖Sx‖² = ‖x‖².
        let scale = 1.0 / (self.d as f64).sqrt();
        let mut buf = vec![0f64; self.mpad];
        for j in 0..n {
            for v in buf.iter_mut() {
                *v = 0.0;
            }
            for i in 0..self.m {
                buf[i] = (self.signs[i] * a.get(i, j)) as f64;
            }
            Self::fwht(&mut buf);
            for (k, &r) in self.rows.iter().enumerate() {
                out.set(k, j, (buf[r] * scale) as f32);
            }
        }
        out
    }

    fn to_dense(&self) -> Mat {
        // Apply to the identity.
        self.apply(&Mat::eye(self.m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fwht_matches_definition_small() {
        // H₂ = [[1,1],[1,-1]] ⊗ …, unnormalized.
        let mut buf = vec![1.0, 2.0, 3.0, 4.0];
        SrhtSketch::fwht(&mut buf);
        assert_eq!(buf, vec![10.0, -2.0, -4.0, 0.0]);
    }

    #[test]
    fn fwht_is_self_inverse_up_to_n() {
        let mut buf: Vec<f64> = (0..8).map(|i| i as f64).collect();
        let orig = buf.clone();
        SrhtSketch::fwht(&mut buf);
        SrhtSketch::fwht(&mut buf);
        for (a, b) in buf.iter().zip(&orig) {
            assert!((a / 8.0 - b).abs() < 1e-12);
        }
    }

    #[test]
    fn handles_non_power_of_two_input() {
        let s = SrhtSketch::new(48, 12, 4);
        assert_eq!(s.mpad, 64);
        let a = Mat::randn(48, 2, &mut Philox::seeded(3));
        assert_eq!(s.apply(&a).shape(), (12, 2));
    }

    #[test]
    #[should_panic(expected = "exceeds padded input size")]
    fn oversized_d_rejected() {
        // m=3 pads to 4; asking for 100 output rows is a caller bug and must
        // fail loudly rather than silently shrink the sketch.
        let _ = SrhtSketch::new(3, 100, 1);
    }

    #[test]
    fn d_equal_to_padded_size_allowed() {
        let s = SrhtSketch::new(3, 4, 1);
        assert_eq!(s.output_dim(), 4);
        let a = Mat::randn(3, 2, &mut Philox::seeded(9));
        assert_eq!(s.apply(&a).shape(), (4, 2));
    }
}
