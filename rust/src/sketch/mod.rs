//! Sketching operators — the "R" in RandNLA.
//!
//! A sketch is a random linear map `S : R^m → R^d` (d ≪ m) that preserves
//! geometry with high probability (Johnson–Lindenstrauss). Panther uses
//! sketches in three places: compressing layer weights (SKLinear/SKConv2d),
//! the rangefinder inside RSVD, and the pivot-selection step of CQRRPT.
//!
//! Implemented operators:
//! - [`GaussianSketch`] — dense i.i.d. N(0, 1/d); the JL workhorse.
//! - [`SparseSignSketch`] — Achlioptas/"short-axis" sparse ±1, `nnz` per
//!   column; the operator CQRRPT recommends for tall inputs.
//! - [`CountSketch`] — one nonzero per column; O(nnz(A)) application.
//! - [`SrhtSketch`] — subsampled randomized Hadamard transform; O(m log m)
//!   apply with strong uniformity guarantees.
//!
//! All operators are deterministic functions of `(seed, shape)` via Philox
//! streams, so distributed workers can regenerate any block on demand
//! without storing the sketch.

mod countsketch;
mod gaussian;
mod sparse_sign;
mod srht;

pub use countsketch::CountSketch;
pub use gaussian::GaussianSketch;
pub use sparse_sign::SparseSignSketch;
pub use srht::SrhtSketch;

use crate::linalg::Mat;

/// A random linear sketching operator `S: R^m -> R^d` applied to matrices
/// with `m` rows: `sketch(A) = S·A` has shape `d × n`.
pub trait Sketch {
    /// Input dimension `m` (rows consumed).
    fn input_dim(&self) -> usize;

    /// Output dimension `d` (rows produced).
    fn output_dim(&self) -> usize;

    /// Apply to a matrix: `S · a`, where `a` is `m × n`.
    fn apply(&self, a: &Mat) -> Mat;

    /// Materialize `S` as a dense `d × m` matrix (tests / small cases).
    fn to_dense(&self) -> Mat;
}

/// Embedding distortion of a sketch on a set of vectors: max over columns of
/// `|‖Sx‖²/‖x‖² − 1|`. Used by tests to check JL concentration.
pub fn max_distortion(s: &dyn Sketch, a: &Mat) -> f64 {
    let sa = s.apply(a);
    let mut worst = 0f64;
    for j in 0..a.cols() {
        let orig: f64 = (0..a.rows()).map(|i| (a.get(i, j) as f64).powi(2)).sum();
        let skch: f64 = (0..sa.rows()).map(|i| (sa.get(i, j) as f64).powi(2)).sum();
        if orig > 1e-30 {
            worst = worst.max((skch / orig - 1.0).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    fn operators(m: usize, d: usize, seed: u64) -> Vec<Box<dyn Sketch>> {
        vec![
            Box::new(GaussianSketch::new(m, d, seed)),
            Box::new(SparseSignSketch::new(m, d, 8.min(d), seed)),
            Box::new(CountSketch::new(m, d, seed)),
            Box::new(SrhtSketch::new(m, d, seed)),
        ]
    }

    #[test]
    fn apply_matches_dense_materialization() {
        let mut rng = Philox::seeded(61);
        let a = Mat::randn(64, 9, &mut rng);
        for op in operators(64, 16, 7) {
            let fast = op.apply(&a);
            let dense = crate::linalg::matmul(&op.to_dense(), &a);
            assert!(
                crate::linalg::rel_error(&fast, &dense) < 1e-4,
                "operator dim {}x{}",
                op.output_dim(),
                op.input_dim()
            );
        }
    }

    #[test]
    fn shapes() {
        for op in operators(100, 20, 3) {
            assert_eq!(op.input_dim(), 100);
            assert_eq!(op.output_dim(), 20);
            let a = Mat::zeros(100, 5);
            assert_eq!(op.apply(&a).shape(), (20, 5));
            assert_eq!(op.to_dense().shape(), (20, 100));
        }
    }

    #[test]
    fn jl_concentration_gaussian() {
        // With d = 512 rows, distortion on a handful of vectors should be
        // well under 30% with overwhelming probability.
        let mut rng = Philox::seeded(62);
        let a = Mat::randn(256, 4, &mut rng);
        let s = GaussianSketch::new(256, 512, 11);
        assert!(max_distortion(&s, &a) < 0.3);
    }

    #[test]
    fn property_norm_preservation_in_expectation() {
        // Averaged over many seeds, ‖Sx‖² ≈ ‖x‖² for every operator family.
        prop_check("sketch-unbiased", 4, |g| {
            let m = 32 + g.usize(0..32);
            let d = 16;
            let x = Mat::randn(m, 1, g.rng());
            let orig: f64 = (0..m).map(|i| (x.get(i, 0) as f64).powi(2)).sum();
            for family in 0..4usize {
                let mut acc = 0f64;
                let trials = 48;
                for t in 0..trials {
                    let seed = (family * 1000 + t) as u64;
                    let op: Box<dyn Sketch> = match family {
                        0 => Box::new(GaussianSketch::new(m, d, seed)),
                        1 => Box::new(SparseSignSketch::new(m, d, 4, seed)),
                        2 => Box::new(CountSketch::new(m, d, seed)),
                        _ => Box::new(SrhtSketch::new(m, d, seed)),
                    };
                    let sx = op.apply(&x);
                    acc += (0..d).map(|i| (sx.get(i, 0) as f64).powi(2)).sum::<f64>();
                }
                let mean = acc / trials as f64;
                let ratio = mean / orig;
                assert!(
                    (0.55..1.45).contains(&ratio),
                    "family {family}: E‖Sx‖²/‖x‖² = {ratio}"
                );
            }
        });
    }

    #[test]
    fn deterministic_in_seed() {
        let mut rng = Philox::seeded(63);
        let a = Mat::randn(50, 3, &mut rng);
        for (x, y) in operators(50, 10, 5)
            .into_iter()
            .zip(operators(50, 10, 5))
        {
            assert_eq!(x.apply(&a).data(), y.apply(&a).data());
        }
    }
}
