//! Sparse-sign ("short-axis") sketch: each input coordinate is scattered to
//! `nnz` random output rows with random signs, scaled by 1/√nnz. This is the
//! operator the CQRRPT paper uses for its pivot sketch — O(nnz·n) apply,
//! embedding quality close to Gaussian for nnz ≳ 8.

use super::Sketch;
use crate::linalg::Mat;
use crate::rng::{Philox, Rng};

pub struct SparseSignSketch {
    m: usize,
    d: usize,
    nnz: usize,
    seed: u64,
}

impl SparseSignSketch {
    pub fn new(m: usize, d: usize, nnz: usize, seed: u64) -> Self {
        assert!(d > 0 && m > 0);
        let nnz = nnz.clamp(1, d);
        SparseSignSketch { m, d, nnz, seed }
    }

    /// The nonzero pattern for input coordinate `j`: `nnz` distinct rows and
    /// signs, from a per-column Philox stream.
    fn column_pattern(&self, j: usize) -> Vec<(usize, f32)> {
        let mut rng = Philox::new(self.seed, j as u64);
        let scale = 1.0 / (self.nnz as f32).sqrt();
        // Sample `nnz` distinct rows via partial Fisher-Yates on indices.
        let mut out = Vec::with_capacity(self.nnz);
        let mut chosen = std::collections::HashSet::with_capacity(self.nnz);
        while out.len() < self.nnz {
            let r = rng.next_below(self.d as u32) as usize;
            if chosen.insert(r) {
                out.push((r, rng.next_sign() * scale));
            }
        }
        out
    }
}

impl Sketch for SparseSignSketch {
    fn input_dim(&self) -> usize {
        self.m
    }

    fn output_dim(&self) -> usize {
        self.d
    }

    fn apply(&self, a: &Mat) -> Mat {
        assert_eq!(a.rows(), self.m);
        let n = a.cols();
        let mut out = Mat::zeros(self.d, n);
        // Scatter each input row into its nnz output rows.
        for srow in 0..self.m {
            let arow = a.row(srow);
            for (drow, sign) in self.column_pattern(srow) {
                let orow = out.row_mut(drow);
                for (o, &v) in orow.iter_mut().zip(arow) {
                    *o += sign * v;
                }
            }
        }
        out
    }

    fn to_dense(&self) -> Mat {
        let mut s = Mat::zeros(self.d, self.m);
        for j in 0..self.m {
            for (i, v) in self.column_pattern(j) {
                s.set(i, j, v);
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pattern_has_exactly_nnz_distinct_rows() {
        let s = SparseSignSketch::new(50, 16, 6, 3);
        for j in 0..50 {
            let p = s.column_pattern(j);
            assert_eq!(p.len(), 6);
            let rows: std::collections::HashSet<usize> = p.iter().map(|&(r, _)| r).collect();
            assert_eq!(rows.len(), 6, "rows must be distinct");
        }
    }

    #[test]
    fn column_norms_are_one() {
        let s = SparseSignSketch::new(30, 16, 4, 5);
        let d = s.to_dense();
        for j in 0..30 {
            let norm2: f32 = (0..16).map(|i| d.get(i, j).powi(2)).sum();
            assert!((norm2 - 1.0).abs() < 1e-5, "col {j} norm² {norm2}");
        }
    }

    #[test]
    fn nnz_clamped_to_d() {
        let s = SparseSignSketch::new(10, 4, 100, 1);
        assert_eq!(s.column_pattern(0).len(), 4);
    }
}
