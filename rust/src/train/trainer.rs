//! Native trainer: loss → backward → optimizer step over any
//! [`crate::nn::Model`] — including one mid-compressed by
//! [`crate::nn::SketchPlan`], which is the paper's headline training
//! workload (sketchify a pretrained model, then fine-tune the factors).
//!
//! This is the `nn`-side counterpart of the artifact-driven
//! [`super::BertTrainer`]/[`super::ConvTrainer`]: those replay compiled
//! train graphs positionally; this one differentiates the live layer
//! registry through [`crate::nn::Module::backward`], so *any* architecture
//! expressible as a layer stack trains without an AOT artifact.
//! Checkpoints reuse the v2 format — parameters in the `param` slots,
//! optimizer moments in the `m`/`v` slots, optimizer identity in the
//! optional trailing section — so fine-tuning resumes exactly.

use super::checkpoint;
use super::optimizer::{optimizer_from_meta, Optimizer};
use crate::linalg::Mat;
use crate::nn::{ForwardCtx, Model};
use crate::runtime::HostTensor;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Mean-squared-error loss: `L = mean((pred − target)²)` over all
/// elements. Returns the scalar loss (f64-accumulated) and `∂L/∂pred`.
pub fn mse_loss(pred: &Mat, target: &Mat) -> (f32, Mat) {
    assert_eq!(pred.shape(), target.shape(), "loss shape mismatch");
    let n = pred.len().max(1) as f64;
    let mut loss = 0f64;
    let mut grad = Mat::zeros(pred.rows(), pred.cols());
    for i in 0..pred.rows() {
        let (pr, tr) = (pred.row(i), target.row(i));
        for (j, gv) in grad.row_mut(i).iter_mut().enumerate() {
            let diff = pr[j] as f64 - tr[j] as f64;
            loss += diff * diff;
            *gv = (2.0 * diff / n) as f32;
        }
    }
    ((loss / n) as f32, grad)
}

/// Loss-only variant of [`mse_loss`] for evaluation paths — no gradient
/// matrix is allocated.
pub fn mse_value(pred: &Mat, target: &Mat) -> f32 {
    assert_eq!(pred.shape(), target.shape(), "loss shape mismatch");
    let n = pred.len().max(1) as f64;
    let loss: f64 = pred
        .data()
        .iter()
        .zip(target.data())
        .map(|(&p, &t)| (p as f64 - t as f64).powi(2))
        .sum();
    (loss / n) as f32
}

/// Token-level masked softmax cross-entropy — the BERT MLM loss. `logits`
/// is `rows × vocab`, `targets[i]` the target class of row `i`, and
/// `mask[i]` the per-row weight: 0 excludes a row (pad positions,
/// un-masked tokens), any positive weight includes it. Loss is the
/// weighted mean of per-row `−log softmax(logits_i)[targets_i]` over
/// included rows (f64 log-sum-exp accumulation); the gradient of an
/// included row is `mask_i·(softmax(logits_i) − onehot_i)/Σmask`, and
/// excluded rows get exactly zero gradient — which is what lets the
/// sequence-aware backward ignore pad rows structurally.
/// `mask` can come straight from
/// [`crate::nn::SeqBatch::token_mask`](crate::nn::SeqBatch).
pub fn masked_xent_loss(logits: &Mat, targets: &[usize], mask: &[f32]) -> (f32, Mat) {
    let (rows, vocab) = logits.shape();
    assert_eq!(targets.len(), rows, "targets/rows mismatch");
    assert_eq!(mask.len(), rows, "mask/rows mismatch");
    let denom: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1e-12);
    let mut loss = 0f64;
    let mut grad = Mat::zeros(rows, vocab);
    for i in 0..rows {
        let mi = mask[i] as f64;
        if mi == 0.0 {
            continue;
        }
        let t = targets[i];
        assert!(t < vocab, "target {t} out of vocab {vocab} at row {i}");
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse: f64 = row.iter().map(|&v| (v as f64 - mx).exp()).sum::<f64>().ln() + mx;
        loss += mi * (lse - row[t] as f64);
        let g = grad.row_mut(i);
        let w = mi / denom;
        for (j, gv) in g.iter_mut().enumerate() {
            let p = (row[j] as f64 - lse).exp();
            *gv = (w * (p - if j == t { 1.0 } else { 0.0 })) as f32;
        }
    }
    ((loss / denom) as f32, grad)
}

/// Loss-only variant of [`masked_xent_loss`] for evaluation paths.
pub fn masked_xent_value(logits: &Mat, targets: &[usize], mask: &[f32]) -> f32 {
    let (rows, vocab) = logits.shape();
    assert_eq!(targets.len(), rows, "targets/rows mismatch");
    assert_eq!(mask.len(), rows, "mask/rows mismatch");
    let denom: f64 = mask.iter().map(|&m| m as f64).sum::<f64>().max(1e-12);
    let mut loss = 0f64;
    for i in 0..rows {
        let mi = mask[i] as f64;
        if mi == 0.0 {
            continue;
        }
        let t = targets[i];
        assert!(t < vocab, "target {t} out of vocab {vocab} at row {i}");
        let row = logits.row(i);
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse: f64 = row.iter().map(|&v| (v as f64 - mx).exp()).sum::<f64>().ln() + mx;
        loss += mi * (lse - row[t] as f64);
    }
    (loss / denom) as f32
}

/// Global-norm gradient clipping: if the L2 norm over *all* accumulated
/// gradients exceeds `max_norm`, every gradient is scaled by
/// `max_norm / norm` so the global norm lands exactly on the threshold
/// (PyTorch's `clip_grad_norm_` semantics). Call between
/// [`Model::backward`] and [`Optimizer::step`]. Returns the pre-clip norm;
/// non-finite norms (an already-exploded backward) zero the gradients
/// outright — `zero_grads`, not a scale by 0, since `0·Inf = NaN` would
/// smuggle the very NaNs into the optimizer moments this guard exists to
/// stop.
pub fn clip_grad_norm(model: &mut Model, max_norm: f32) -> f64 {
    assert!(max_norm > 0.0, "clip_grad_norm wants a positive threshold");
    let norm = model.grad_norm();
    if !norm.is_finite() {
        model.zero_grads();
        return norm;
    }
    if norm > max_norm as f64 {
        model.scale_grads((max_norm as f64 / norm) as f32);
    }
    norm
}

/// Runs `loss → backward → step` over a [`Model`] with any
/// [`Optimizer`]. Holds the step counter so checkpoints resume the
/// optimizer schedule (Adam bias correction) exactly.
pub struct Trainer {
    pub opt: Box<dyn Optimizer>,
    /// Training steps taken (mirrors the checkpoint `step` field).
    pub step: u64,
    /// Global-norm gradient-clip threshold applied between backward and
    /// the optimizer step; `None` disables clipping. A hyperparameter
    /// knob, not training state — it is not persisted in checkpoints, so
    /// re-set it after [`Trainer::resume`].
    pub clip_norm: Option<f32>,
}

impl Trainer {
    pub fn new(opt: Box<dyn Optimizer>) -> Self {
        Trainer {
            opt,
            step: 0,
            clip_norm: None,
        }
    }

    /// Enable global-norm gradient clipping at `max_norm`.
    pub fn with_clip_norm(mut self, max_norm: f32) -> Self {
        assert!(max_norm > 0.0, "clip norm must be positive");
        self.clip_norm = Some(max_norm);
        self
    }

    /// One MSE training step on `(x, target)`: zero grads, training
    /// forward, backward, optional global-norm clip, optimizer update.
    /// Returns the pre-update loss.
    pub fn train_step(
        &mut self,
        model: &mut Model,
        x: &Mat,
        target: &Mat,
        ctx: &ForwardCtx,
    ) -> Result<f32> {
        model.zero_grads();
        let (pred, caches) = model.forward_train(x, ctx)?;
        ensure!(
            pred.shape() == target.shape(),
            "model output {:?} vs target {:?}",
            pred.shape(),
            target.shape()
        );
        let (loss, dloss) = mse_loss(&pred, target);
        model.backward(&dloss, &caches, ctx)?;
        if let Some(max_norm) = self.clip_norm {
            clip_grad_norm(model, max_norm);
        }
        self.opt.step(model)?;
        self.step += 1;
        Ok(loss)
    }

    /// One masked-cross-entropy training step — the token-level MLM
    /// objective. `x` is the packed/padded token-feature matrix, `targets`
    /// one class per row, `mask` the per-row weights (use
    /// [`crate::nn::SeqBatch::token_mask`] for padded batches, and install
    /// the same `SeqBatch` on `ctx` so the attention layers mask
    /// structurally). Returns the pre-update loss.
    pub fn train_step_masked_ce(
        &mut self,
        model: &mut Model,
        x: &Mat,
        targets: &[usize],
        mask: &[f32],
        ctx: &ForwardCtx,
    ) -> Result<f32> {
        model.zero_grads();
        let (logits, caches) = model.forward_train(x, ctx)?;
        ensure!(
            logits.rows() == targets.len() && logits.rows() == mask.len(),
            "model output rows {} vs {} targets / {} mask entries",
            logits.rows(),
            targets.len(),
            mask.len()
        );
        let (loss, dloss) = masked_xent_loss(&logits, targets, mask);
        model.backward(&dloss, &caches, ctx)?;
        if let Some(max_norm) = self.clip_norm {
            clip_grad_norm(model, max_norm);
        }
        self.opt.step(model)?;
        self.step += 1;
        Ok(loss)
    }

    /// MSE eval loss without touching gradients or parameters.
    pub fn eval_loss(&self, model: &Model, x: &Mat, target: &Mat, ctx: &ForwardCtx) -> Result<f32> {
        let pred = model.forward(x, ctx)?;
        ensure!(
            pred.shape() == target.shape(),
            "model output {:?} vs target {:?}",
            pred.shape(),
            target.shape()
        );
        Ok(mse_value(&pred, target))
    }

    /// Checkpoint model parameters + optimizer moments + optimizer
    /// identity (v2 file with the optional optimizer section). `tag` is
    /// the checkpoint's model-name field.
    pub fn save_checkpoint(&self, model: &Model, tag: &str, path: impl AsRef<Path>) -> Result<()> {
        let sd = model.state_dict();
        let (m, v) = self.opt.export_moments(&sd);
        let (names, params): (Vec<String>, Vec<HostTensor>) = sd.into_iter().unzip();
        let state = super::ModelState {
            model: tag.to_string(),
            names,
            params,
            m,
            v,
            step: self.step,
        };
        checkpoint::save_with_optimizer(&state, Some(&self.opt.meta()), path)
    }

    /// Restore a trainer (optimizer kind, scalar state, moments, step
    /// counter) and `model`'s parameters from a checkpoint written by
    /// [`Trainer::save_checkpoint`]. The model must already have the
    /// matching architecture — the same contract as
    /// [`Model::load_state_dict`]. `clip_norm` is a knob, not state: it
    /// resumes as `None`; re-apply [`Trainer::with_clip_norm`] if the run
    /// used clipping.
    pub fn resume(model: &mut Model, path: impl AsRef<Path>) -> Result<Trainer> {
        let (state, meta) = checkpoint::load_with_optimizer(&path)?;
        let meta = meta.with_context(|| {
            format!(
                "checkpoint {:?} has no optimizer section — was it written by Trainer::save_checkpoint?",
                path.as_ref()
            )
        })?;
        model
            .load_state_dict(&state.state_dict())
            .context("restoring model parameters")?;
        let mut opt = optimizer_from_meta(&meta)?;
        opt.import_moments(&state.names, &state.m, &state.v)
            .context("restoring optimizer moments")?;
        Ok(Trainer {
            opt,
            step: state.step,
            clip_norm: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Linear;
    use crate::rng::Philox;
    use crate::train::optimizer::{Adam, Sgd};

    fn toy_model(seed: u64) -> Model {
        let mut rng = Philox::seeded(seed);
        let mut m = Model::new();
        m.add("fc1", Linear::random(6, 10, &mut rng)).unwrap();
        m.add("fc2", Linear::random(10, 4, &mut rng)).unwrap();
        m
    }

    fn toy_batch(seed: u64) -> (Mat, Mat) {
        let mut rng = Philox::seeded(seed);
        let x = Mat::randn(16, 6, &mut rng);
        let teacher = Linear::random(6, 4, &mut rng);
        let y = teacher.forward(&x);
        (x, y)
    }

    #[test]
    fn sgd_reduces_mse_on_linear_regression() {
        let mut model = toy_model(1);
        let (x, y) = toy_batch(2);
        let ctx = ForwardCtx::new();
        let mut tr = Trainer::new(Box::new(Sgd::new(0.05)));
        let first = tr.train_step(&mut model, &x, &y, &ctx).unwrap();
        let mut last = first;
        for _ in 0..30 {
            last = tr.train_step(&mut model, &x, &y, &ctx).unwrap();
        }
        assert!(last < first * 0.5, "loss {first} -> {last}");
        assert_eq!(tr.step, 31);
    }

    #[test]
    fn adam_reduces_mse_and_checkpoint_resumes_exactly() {
        let mut model = toy_model(3);
        let (x, y) = toy_batch(4);
        let ctx = ForwardCtx::new();
        let mut tr = Trainer::new(Box::new(Adam::new(0.01)));
        for _ in 0..5 {
            tr.train_step(&mut model, &x, &y, &ctx).unwrap();
        }
        let dir = std::env::temp_dir().join("panther_trainer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("resume.ckpt");
        tr.save_checkpoint(&model, "toy", &path).unwrap();

        // Branch A: keep training in-memory.
        let mut model_a = model.clone_model();
        let mut tr_a = Trainer {
            opt: tr.opt,
            step: tr.step,
            clip_norm: None,
        };
        let mut losses_a = Vec::new();
        for _ in 0..5 {
            losses_a.push(tr_a.train_step(&mut model_a, &x, &y, &ctx).unwrap());
        }

        // Branch B: resume from the checkpoint into a fresh model.
        let mut model_b = toy_model(999); // same architecture, different init
        let mut tr_b = Trainer::resume(&mut model_b, &path).unwrap();
        assert_eq!(tr_b.step, 5);
        let mut losses_b = Vec::new();
        for _ in 0..5 {
            losses_b.push(tr_b.train_step(&mut model_b, &x, &y, &ctx).unwrap());
        }
        // Deterministic math, identical state — identical loss curves.
        assert_eq!(losses_a, losses_b, "resume must continue exactly");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_without_optimizer_section_errors() {
        let model = toy_model(5);
        let sd = model.state_dict();
        let (names, params): (Vec<String>, Vec<HostTensor>) = sd.into_iter().unzip();
        let zeros: Vec<HostTensor> = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        let state = crate::train::ModelState {
            model: "toy".into(),
            names,
            params,
            m: zeros.clone(),
            v: zeros,
            step: 0,
        };
        let dir = std::env::temp_dir().join("panther_trainer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("no_opt.ckpt");
        checkpoint::save(&state, &path).unwrap();
        let mut m2 = toy_model(5);
        let err = Trainer::resume(&mut m2, &path);
        assert!(err.is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn clip_grad_norm_caps_a_crafted_exploding_gradient() {
        // A teacher/student mismatch scaled by 1e3 explodes the MSE
        // gradient; the clip must land the global norm exactly on the
        // threshold, scaling every layer's gradients uniformly.
        let mut model = toy_model(7);
        for layer in model.iter_mut() {
            for (_, mut p) in layer.module.params_mut() {
                for v in p.data_mut() {
                    *v *= 1e3;
                }
            }
            layer.module.on_params_loaded();
        }
        let (x, y) = toy_batch(8);
        let ctx = ForwardCtx::new();
        let (pred, caches) = model.forward_train(&x, &ctx).unwrap();
        let (_, dloss) = mse_loss(&pred, &y);
        model.backward(&dloss, &caches, &ctx).unwrap();
        let max_norm = 1.0f32;
        let pre = model.grad_norm();
        assert!(pre > 100.0, "gradient should explode, norm {pre}");
        // Per-parameter snapshot to verify uniform scaling.
        let before: Vec<Vec<f32>> = model
            .iter()
            .flat_map(|l| l.module.grads().into_iter().map(|(_, g)| g.to_vec()))
            .collect();
        let reported = clip_grad_norm(&mut model, max_norm);
        assert_eq!(reported, pre, "returns the pre-clip norm");
        let post = model.grad_norm();
        assert!(
            (post - max_norm as f64).abs() < 1e-3,
            "clipped norm {post} != {max_norm}"
        );
        let after: Vec<Vec<f32>> = model
            .iter()
            .flat_map(|l| l.module.grads().into_iter().map(|(_, g)| g.to_vec()))
            .collect();
        let s = (max_norm as f64 / pre) as f32;
        for (b, a) in before.iter().zip(&after) {
            for (bv, av) in b.iter().zip(a) {
                assert!((bv * s - av).abs() <= 1e-6 * bv.abs().max(1.0));
            }
        }
        // Under the threshold: a no-op.
        let small = clip_grad_norm(&mut model, 10.0);
        assert!((small - post).abs() < 1e-9);
        assert_eq!(model.grad_norm(), post);
    }

    #[test]
    fn clip_grad_norm_zeroes_non_finite_gradients() {
        // Weights large enough to overflow f32 in the forward: the
        // gradients come back Inf/NaN, the norm is non-finite, and the
        // guard must *zero* them (a scale by 0 would keep NaNs: 0·Inf).
        let mut model = toy_model(11);
        for layer in model.iter_mut() {
            for (_, mut p) in layer.module.params_mut() {
                for v in p.data_mut() {
                    *v *= 1e20;
                }
            }
            layer.module.on_params_loaded();
        }
        let (x, y) = toy_batch(12);
        let ctx = ForwardCtx::new();
        let (pred, caches) = model.forward_train(&x, &ctx).unwrap();
        assert!(
            pred.data().iter().any(|v| !v.is_finite()),
            "forward should overflow (guards the test)"
        );
        let (_, dloss) = mse_loss(&pred, &y);
        model.backward(&dloss, &caches, &ctx).unwrap();
        let norm = clip_grad_norm(&mut model, 1.0);
        assert!(!norm.is_finite(), "norm should report the explosion");
        for l in model.iter() {
            for (_, g) in l.module.grads() {
                assert!(g.iter().all(|&v| v == 0.0), "grads zeroed, not NaN");
            }
        }
    }

    #[test]
    fn trainer_clip_knob_keeps_exploding_sgd_finite() {
        // Without clipping, SGD at lr=0.5 on the 1e3-scaled model blows up
        // within a few steps; with a global-norm clip the updates stay
        // bounded and every loss remains finite.
        let build_exploded = || {
            let mut m = toy_model(9);
            for layer in m.iter_mut() {
                for (_, mut p) in layer.module.params_mut() {
                    for v in p.data_mut() {
                        *v *= 1e3;
                    }
                }
                layer.module.on_params_loaded();
            }
            m
        };
        let (x, y) = toy_batch(10);
        let ctx = ForwardCtx::new();
        let mut unclipped = build_exploded();
        let mut tr_u = Trainer::new(Box::new(Sgd::new(0.5)));
        let mut diverged = false;
        for _ in 0..8 {
            let loss = tr_u.train_step(&mut unclipped, &x, &y, &ctx).unwrap();
            if !loss.is_finite() {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "unclipped run should diverge (guards the test)");
        let mut clipped = build_exploded();
        let mut tr_c = Trainer::new(Box::new(Sgd::new(0.5))).with_clip_norm(1.0);
        assert_eq!(tr_c.clip_norm, Some(1.0));
        for _ in 0..8 {
            let loss = tr_c.train_step(&mut clipped, &x, &y, &ctx).unwrap();
            assert!(loss.is_finite(), "clipped run must stay finite");
        }
        for (_, t) in clipped.state_dict() {
            assert!(t.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn masked_xent_gradient_matches_finite_differences() {
        // f64 central differences on the analytic gradient, row by row,
        // including a zero-mask row (must have exactly zero gradient) and
        // a non-uniform weight.
        let mut rng = Philox::seeded(42);
        let logits = Mat::randn(4, 7, &mut rng);
        let targets = [2usize, 5, 0, 3];
        let mask = [1.0f32, 0.0, 2.0, 1.0];
        let (loss, grad) = masked_xent_loss(&logits, &targets, &mask);
        assert_eq!(loss, masked_xent_value(&logits, &targets, &mask));
        assert!(grad.row(1).iter().all(|&g| g == 0.0), "pad row grad != 0");
        let eps = 1e-3f32;
        for i in 0..4 {
            for j in 0..7 {
                let mut lp = logits.clone();
                lp.row_mut(i)[j] += eps;
                let mut lm = logits.clone();
                lm.row_mut(i)[j] -= eps;
                let fd = (masked_xent_value(&lp, &targets, &mask) as f64
                    - masked_xent_value(&lm, &targets, &mask) as f64)
                    / (2.0 * eps as f64);
                let an = grad.row(i)[j] as f64;
                assert!(
                    (fd - an).abs() <= 1e-4 + 1e-3 * an.abs(),
                    "grad[{i}][{j}]: fd {fd} vs analytic {an}"
                );
            }
        }
        // Included-row gradients sum to ~0 per row (softmax minus onehot).
        for i in [0usize, 2, 3] {
            let s: f64 = grad.row(i).iter().map(|&g| g as f64).sum();
            assert!(s.abs() < 1e-6, "row {i} grad sum {s}");
        }
    }

    #[test]
    fn masked_xent_ignores_pad_rows_entirely() {
        // Perturbing an excluded row's logits must not move the loss.
        let mut rng = Philox::seeded(43);
        let mut logits = Mat::randn(3, 5, &mut rng);
        let targets = [1usize, 4, 2];
        let mask = [1.0f32, 0.0, 1.0];
        let base = masked_xent_value(&logits, &targets, &mask);
        for v in logits.row_mut(1) {
            *v += 100.0;
        }
        assert_eq!(base, masked_xent_value(&logits, &targets, &mask));
        // All-zero mask: loss is 0 (denom clamp), grad is all-zero.
        let (l0, g0) = masked_xent_loss(&logits, &targets, &[0.0; 3]);
        assert_eq!(l0, 0.0);
        assert!(g0.data().iter().all(|&g| g == 0.0));
    }

    #[test]
    fn masked_ce_training_reduces_loss_on_toy_classification() {
        let mut model = toy_model(21);
        let mut rng = Philox::seeded(22);
        let x = Mat::randn(16, 6, &mut rng);
        // Fixed random labels over the 4 output classes; every other row
        // masked out, as an MLM batch would.
        let targets: Vec<usize> = (0..16).map(|i| (i * 7 + 3) % 4).collect();
        let mask: Vec<f32> = (0..16).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let ctx = ForwardCtx::new();
        let mut tr = Trainer::new(Box::new(Adam::new(0.02)));
        let first = tr
            .train_step_masked_ce(&mut model, &x, &targets, &mask, &ctx)
            .unwrap();
        let mut last = first;
        for _ in 0..40 {
            last = tr
                .train_step_masked_ce(&mut model, &x, &targets, &mask, &ctx)
                .unwrap();
        }
        assert!(last < first * 0.5, "CE loss {first} -> {last}");
        assert_eq!(tr.step, 41);
    }

    #[test]
    fn optimizer_step_skips_layers_without_grads() {
        // A model that never ran backward: step must be a clean no-op.
        let mut model = toy_model(6);
        let before = model.state_dict();
        let mut opt = Sgd::new(0.1);
        opt.step(&mut model).unwrap();
        assert_eq!(model.state_dict(), before);
    }
}
