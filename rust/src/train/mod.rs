//! Training drivers.
//!
//! Two paths coexist:
//!
//! - the **artifact path** ([`BertTrainer`], [`ConvTrainer`]): replays
//!   compiled `init`/`train`/`eval` HLO graphs positionally — the model
//!   state (parameters + Adam moments) lives host-side as [`HostTensor`]s
//!   in the manifest's canonical order;
//! - the **native path** ([`Trainer`] + [`Optimizer`]): differentiates a
//!   live [`crate::nn::Model`] through [`crate::nn::Module::backward`], so
//!   any layer stack — including one compressed mid-flight by
//!   [`crate::nn::SketchPlan`] — trains and fine-tunes without an AOT
//!   artifact.
//!
//! Both serialize through [`checkpoint`] (v3, name-keyed and CRC32
//! checksummed; the native trainer adds the optional optimizer section so
//! resumes are exact).

pub mod checkpoint;
pub mod optimizer;
pub mod schedule;
pub mod trainer;

pub use optimizer::{optimizer_from_meta, Adam, OptimMeta, Optimizer, Sgd};
pub use schedule::{LrSchedule, ScheduledOpt};
pub use trainer::{
    clip_grad_norm, masked_xent_loss, masked_xent_value, mse_loss, mse_value, Trainer,
};

use crate::data::{MaskedBatch, TextCorpus};
use crate::rng::Philox;
use crate::runtime::{HostTensor, ModelSpec, Runtime};
use anyhow::{bail, Context, Result};

/// Host-side model state: params + Adam moments, in manifest order, plus
/// the manifest's parameter *names* — checkpoints (v3) and the serving path
/// key tensors by name, the executable boundary stays positional.
pub struct ModelState {
    pub model: String,
    /// One name per entry of `params`/`m`/`v` (the manifest's
    /// `param_names`). States restored from legacy v1 checkpoints carry
    /// synthesized positional names (`param.0`, `param.1`, …).
    pub names: Vec<String>,
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: u64,
}

impl ModelState {
    /// Initialize by running the model's `init` artifact.
    pub fn init(rt: &mut Runtime, model: &str, seed: f32) -> Result<Self> {
        let spec = rt
            .manifest()
            .model(model)
            .with_context(|| format!("no model {model} in manifest"))?
            .clone();
        let out = rt.execute(&spec.init, &[HostTensor::scalar(seed)])?;
        let n = spec.param_names.len();
        if out.len() != 3 * n {
            bail!(
                "init artifact returned {} tensors, expected 3×{n}",
                out.len()
            );
        }
        let mut it = out.into_iter();
        let params: Vec<_> = (&mut it).take(n).collect();
        let m: Vec<_> = (&mut it).take(n).collect();
        let v: Vec<_> = it.collect();
        Ok(ModelState {
            model: model.to_string(),
            names: spec.param_names.clone(),
            params,
            m,
            v,
            step: 0,
        })
    }

    /// Total parameter count.
    pub fn param_count(&self) -> usize {
        self.params.iter().map(|t| t.len()).sum()
    }

    /// Parameter tensor by name (manifest order lookup).
    pub fn param<'a>(&'a self, spec: &ModelSpec, name: &str) -> Option<&'a HostTensor> {
        let idx = spec.param_names.iter().position(|n| n == name)?;
        self.params.get(idx)
    }

    /// Parameter tensor by its own stored name (no manifest needed).
    pub fn param_named(&self, name: &str) -> Option<&HostTensor> {
        let idx = self.names.iter().position(|n| n == name)?;
        self.params.get(idx)
    }

    /// Name-keyed snapshot of the parameters — the same representation
    /// [`crate::nn::Model::state_dict`] produces, so runtime states and
    /// `nn` models exchange weights through one format. Params beyond the
    /// stored names (hand-built nameless states) get the same synthesized
    /// `param.{i}` keys the checkpoint writer uses for them.
    pub fn state_dict(&self) -> crate::nn::StateDict {
        self.params
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let name = self
                    .names
                    .get(i)
                    .cloned()
                    .unwrap_or_else(|| format!("param.{i}"));
                (name, t.clone())
            })
            .collect()
    }
}

/// Result of one training run.
pub struct TrainReport {
    pub model: String,
    pub steps: u64,
    pub losses: Vec<(u64, f32)>,
    pub final_loss: f32,
    pub wall: std::time::Duration,
}

/// Trainer for BERT-family models (MLM batches).
pub struct BertTrainer<'a> {
    pub rt: &'a mut Runtime,
    pub corpus: &'a TextCorpus,
    pub log_every: u64,
}

impl<'a> BertTrainer<'a> {
    pub fn new(rt: &'a mut Runtime, corpus: &'a TextCorpus) -> Self {
        BertTrainer {
            rt,
            corpus,
            log_every: 25,
        }
    }

    fn batch_dims(&self, spec: &ModelSpec) -> (usize, usize) {
        (
            spec.config_usize("batch").unwrap_or(16),
            spec.config_usize("seq").unwrap_or(64),
        )
    }

    /// Run `steps` training steps; returns the loss curve.
    pub fn train(
        &mut self,
        state: &mut ModelState,
        steps: u64,
        data_rng: &mut Philox,
    ) -> Result<TrainReport> {
        let spec = self
            .rt
            .manifest()
            .model(&state.model)
            .context("model missing")?
            .clone();
        let train_art = spec
            .train
            .clone()
            .with_context(|| format!("model {} has no train artifact", state.model))?;
        let (batch, seq) = self.batch_dims(&spec);
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        let mut final_loss = f32::NAN;
        for s in 0..steps {
            let mb = self.corpus.mlm_batch(batch, seq, data_rng);
            let loss = self.step(state, &train_art, &mb)?;
            final_loss = loss;
            if s % self.log_every == 0 || s + 1 == steps {
                losses.push((state.step, loss));
                crate::log_info!(
                    "{} step {:>5} loss {:.4}",
                    state.model,
                    state.step,
                    loss
                );
            }
        }
        Ok(TrainReport {
            model: state.model.clone(),
            steps,
            losses,
            final_loss,
            wall: t0.elapsed(),
        })
    }

    /// One optimizer step on one batch; updates `state` in place.
    pub fn step(
        &mut self,
        state: &mut ModelState,
        train_art: &str,
        mb: &MaskedBatch,
    ) -> Result<f32> {
        state.step += 1;
        let mut inputs = Vec::with_capacity(3 * state.params.len() + 4);
        inputs.extend(state.params.iter().cloned());
        inputs.extend(state.m.iter().cloned());
        inputs.extend(state.v.iter().cloned());
        inputs.push(HostTensor::scalar(state.step as f32));
        inputs.push(mb.tokens.clone());
        inputs.push(mb.labels.clone());
        inputs.push(mb.mask.clone());
        let out = self.rt.execute(train_art, &inputs)?;
        let n = state.params.len();
        anyhow::ensure!(out.len() == 3 * n + 1, "train output arity");
        let mut it = out.into_iter();
        state.params = (&mut it).take(n).collect();
        state.m = (&mut it).take(n).collect();
        state.v = (&mut it).take(n).collect();
        let loss = it.next().unwrap().to_scalar();
        Ok(loss)
    }

    /// Average eval loss over `batches` fresh MLM batches.
    pub fn evaluate(
        &mut self,
        state: &ModelState,
        batches: usize,
        data_rng: &mut Philox,
    ) -> Result<f32> {
        let spec = self
            .rt
            .manifest()
            .model(&state.model)
            .context("model missing")?
            .clone();
        let eval_art = spec.eval.clone().context("model has no eval artifact")?;
        let (batch, seq) = self.batch_dims(&spec);
        let mut total = 0f64;
        for _ in 0..batches {
            let mb = self.corpus.mlm_batch(batch, seq, data_rng);
            let mut inputs = Vec::with_capacity(state.params.len() + 3);
            inputs.extend(state.params.iter().cloned());
            inputs.push(mb.tokens.clone());
            inputs.push(mb.labels.clone());
            inputs.push(mb.mask.clone());
            let out = self.rt.execute(&eval_art, &inputs)?;
            total += out[0].to_scalar() as f64;
        }
        Ok((total / batches as f64) as f32)
    }

    /// Evaluate *foreign* params (e.g. tuner candidates) through a specific
    /// eval artifact, without a full ModelState.
    pub fn evaluate_params(
        &mut self,
        eval_art: &str,
        params: &[HostTensor],
        batches: usize,
        batch: usize,
        seq: usize,
        data_rng: &mut Philox,
    ) -> Result<f32> {
        let mut total = 0f64;
        for _ in 0..batches {
            let mb = self.corpus.mlm_batch(batch, seq, data_rng);
            let mut inputs = Vec::with_capacity(params.len() + 3);
            inputs.extend(params.iter().cloned());
            inputs.push(mb.tokens.clone());
            inputs.push(mb.labels.clone());
            inputs.push(mb.mask.clone());
            let out = self.rt.execute(eval_art, &inputs)?;
            total += out[0].to_scalar() as f64;
        }
        Ok((total / batches as f64) as f32)
    }
}

/// Trainer for the conv-classifier family.
pub struct ConvTrainer<'a> {
    pub rt: &'a mut Runtime,
    pub data: &'a crate::data::ImageDataset,
}

impl<'a> ConvTrainer<'a> {
    pub fn new(rt: &'a mut Runtime, data: &'a crate::data::ImageDataset) -> Self {
        ConvTrainer { rt, data }
    }

    pub fn train(
        &mut self,
        state: &mut ModelState,
        steps: u64,
        data_rng: &mut Philox,
    ) -> Result<TrainReport> {
        let spec = self
            .rt
            .manifest()
            .model(&state.model)
            .context("model missing")?
            .clone();
        let train_art = spec.train.clone().context("no train artifact")?;
        let batch = spec.config_usize("batch").unwrap_or(32);
        let t0 = std::time::Instant::now();
        let mut losses = Vec::new();
        let mut final_loss = f32::NAN;
        for s in 0..steps {
            let (images, labels) = self.data.batch(batch, data_rng);
            state.step += 1;
            let mut inputs = Vec::with_capacity(3 * state.params.len() + 3);
            inputs.extend(state.params.iter().cloned());
            inputs.extend(state.m.iter().cloned());
            inputs.extend(state.v.iter().cloned());
            inputs.push(HostTensor::scalar(state.step as f32));
            inputs.push(images);
            inputs.push(labels);
            let out = self.rt.execute(&train_art, &inputs)?;
            let n = state.params.len();
            let mut it = out.into_iter();
            state.params = (&mut it).take(n).collect();
            state.m = (&mut it).take(n).collect();
            state.v = (&mut it).take(n).collect();
            final_loss = it.next().unwrap().to_scalar();
            if s % 25 == 0 || s + 1 == steps {
                losses.push((state.step, final_loss));
                crate::log_info!("{} step {:>5} loss {:.4}", state.model, state.step, final_loss);
            }
        }
        Ok(TrainReport {
            model: state.model.clone(),
            steps,
            losses,
            final_loss,
            wall: t0.elapsed(),
        })
    }

    /// Classification accuracy over `batches` fresh batches.
    pub fn accuracy(
        &mut self,
        state: &ModelState,
        batches: usize,
        data_rng: &mut Philox,
    ) -> Result<f64> {
        let spec = self
            .rt
            .manifest()
            .model(&state.model)
            .context("model missing")?
            .clone();
        let predict = spec.predict.clone().context("no predict artifact")?;
        let batch = spec.config_usize("batch").unwrap_or(32);
        let mut acc = 0f64;
        for _ in 0..batches {
            let (images, labels) = self.data.batch(batch, data_rng);
            let mut inputs: Vec<HostTensor> = state.params.to_vec();
            inputs.push(images);
            let out = self.rt.execute(&predict, &inputs)?;
            acc += crate::data::ImageDataset::accuracy(&out[0], &labels);
        }
        Ok(acc / batches as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        Some(Runtime::open(dir).unwrap())
    }

    #[test]
    fn init_produces_state_with_zero_moments() {
        let Some(mut rt) = runtime() else { return };
        let state = ModelState::init(&mut rt, "conv_dense", 1.0).unwrap();
        assert!(state.param_count() > 0);
        assert_eq!(state.params.len(), state.m.len());
        assert!(state
            .m
            .iter()
            .all(|t| t.data().iter().all(|&x| x == 0.0)));
        assert!(state
            .v
            .iter()
            .all(|t| t.data().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn bert_one_step_reduces_nothing_catastrophic() {
        let Some(mut rt) = runtime() else { return };
        let corpus = TextCorpus::generate(256, 5_000, 1);
        let mut state = ModelState::init(&mut rt, "bert_dense", 0.0).unwrap();
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        let mut rng = Philox::seeded(5);
        let report = trainer.train(&mut state, 3, &mut rng).unwrap();
        assert_eq!(report.steps, 3);
        assert!(report.final_loss.is_finite());
        // Initial MLM loss ≈ ln(vocab) ≈ 5.5; one step keeps it sane.
        assert!(report.final_loss < 10.0);
        assert_eq!(state.step, 3);
    }

    #[test]
    fn conv_train_and_accuracy_roundtrip() {
        let Some(mut rt) = runtime() else { return };
        let ds = crate::data::ImageDataset::cifar_like();
        let mut state = ModelState::init(&mut rt, "conv_dense", 2.0).unwrap();
        let mut trainer = ConvTrainer::new(&mut rt, &ds);
        let mut rng = Philox::seeded(6);
        let report = trainer.train(&mut state, 3, &mut rng).unwrap();
        assert!(report.final_loss.is_finite());
        let acc = trainer.accuracy(&state, 2, &mut rng).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }
}
