//! Checkpoints: serialize a [`super::ModelState`] to a simple binary file.
//!
//! Current format — **v3**, name-keyed and checksummed (little-endian):
//! ```text
//! magic "PNTH" | version u32 = 3 | step u64 | model-name (u32 len + utf8)
//! | n_params u32 | n records:
//!     param-name (u32 len + utf8) | rank u32 | dims u64 × rank
//!     | param f32 × prod(dims) | m f32 × prod(dims) | v f32 × prod(dims)
//!     | record CRC32 u32                  (over the record's bytes above)
//! | optional optimizer section (see below)
//! | footer: "PCRC" | file CRC32 u32      (over every byte before "PCRC")
//! ```
//! Tensor payloads are bulk-serialized as little-endian byte chunks
//! (64 KiB staged per IO call — not one write per `f32`, and not a full
//! per-tensor buffer that would double the largest tensor's memory).
//!
//! **Integrity.** Every tensor record carries a CRC32 ([`crate::util::crc`])
//! of its serialized bytes, and the footer carries a CRC32 of the whole
//! file up to (excluding) the footer marker, so header tampering is caught
//! even when every record checksum passes. Loads fail with a typed
//! [`CheckpointError`] — [`CheckpointError::CorruptCheckpoint`] on any
//! checksum mismatch, [`CheckpointError::Truncated`] when the file ends (or
//! a length field claims more bytes than the file holds) before the
//! promised structure is complete, [`CheckpointError::Malformed`] for
//! structural garbage — never a panic, and never a silently different
//! model. Length claims are validated against the file size *before* any
//! allocation they would size, so a bit-flipped length cannot trigger a
//! huge allocation.
//!
//! **Recovery.** [`save`] keeps the previously saved file as a `.bak`
//! sibling (`foo.ckpt` → `foo.ckpt.bak`), and [`load_with_recovery`] falls
//! back to it when the primary is corrupt or truncated.
//!
//! Legacy **v1** files (positional, three groups of shape-prefixed
//! tensors) and **v2** files (name-keyed, no checksums) still load; v1
//! parameters get synthesized positional names `param.{i}` since v1 never
//! stored names.
//!
//! After the records (and before the v3 footer), files may carry an
//! **optional optimizer section**:
//! ```text
//! "OPTS" | kind (u32 len + utf8) | n_hyper u32 | hyper f32 × n_hyper
//! ```
//! written by [`save_with_optimizer`] (the native
//! [`super::Trainer`](super::trainer::Trainer) uses it to persist the
//! optimizer identity and scalar state; the moments themselves ride in the
//! per-record `m`/`v` slots). Readers that don't care ([`load`]) skip it;
//! files without it load as `None` — both directions stay compatible.

use super::optimizer::OptimMeta;
use super::ModelState;
use crate::runtime::HostTensor;
use crate::util::crc::Crc32;
use anyhow::{Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8; 4] = b"PNTH";
const VERSION: u32 = 3;
const OPT_MAGIC: &[u8; 4] = b"OPTS";
const FOOTER_MAGIC: &[u8; 4] = b"PCRC";

/// Typed checkpoint load failure. Every way a load can fail on file
/// *content* (as opposed to e.g. the file not existing) surfaces one of
/// these, reachable through [`anyhow::Error::downcast_ref`] on the returned
/// error — callers can distinguish corruption from truncation from
/// structural garbage without string matching.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// A stored CRC32 does not match the bytes actually read. `record` is
    /// the parameter name, or `"<file>"` for the whole-file footer;
    /// `expected` is the checksum stored in the file, `actual` the one
    /// computed over the bytes.
    CorruptCheckpoint {
        /// Parameter name of the failing record, or `"<file>"`.
        record: String,
        /// Checksum stored in the file.
        expected: u32,
        /// Checksum computed over the bytes read.
        actual: u32,
    },
    /// The file ends — or a length field claims more bytes than the whole
    /// file holds — before the promised structure is complete.
    Truncated {
        /// What was being read when the data ran out.
        detail: String,
    },
    /// Structurally invalid: bad magic, unsupported version, bad utf8,
    /// impossible shapes, or trailing garbage.
    Malformed {
        /// What was wrong.
        detail: String,
    },
}

impl std::fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CheckpointError::CorruptCheckpoint {
                record,
                expected,
                actual,
            } => write!(
                f,
                "checkpoint corrupt: record `{record}` checksum mismatch \
                 (stored {expected:#010x}, computed {actual:#010x})"
            ),
            CheckpointError::Truncated { detail } => {
                write!(f, "checkpoint truncated: {detail}")
            }
            CheckpointError::Malformed { detail } => {
                write!(f, "malformed checkpoint: {detail}")
            }
        }
    }
}

impl std::error::Error for CheckpointError {}

fn malformed(detail: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(CheckpointError::Malformed {
        detail: detail.into(),
    })
}

fn truncated(detail: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(CheckpointError::Truncated {
        detail: detail.into(),
    })
}

/// Write a checkpoint (always the current v3 format). The state is
/// validated up front and the bytes go to a sibling temp file that is
/// renamed into place only on success — a failed save never truncates an
/// existing checkpoint at `path`. On success the previously saved file (if
/// any) is kept as `path.bak` for [`load_with_recovery`].
pub fn save(state: &ModelState, path: impl AsRef<Path>) -> Result<()> {
    save_with_optimizer(state, None, path)
}

/// [`save`] plus an optional trailing optimizer section carrying the
/// optimizer's identity and scalar state (Adam's step counter etc.).
pub fn save_with_optimizer(
    state: &ModelState,
    opt: Option<&OptimMeta>,
    path: impl AsRef<Path>,
) -> Result<()> {
    let path = path.as_ref();
    let n = state.params.len();
    if state.m.len() != n || state.v.len() != n {
        anyhow::bail!(
            "param/moment arity mismatch: {n} params, {} m, {} v",
            state.m.len(),
            state.v.len()
        );
    }
    if !state.names.is_empty() && state.names.len() != n {
        anyhow::bail!("state has {} names for {n} params", state.names.len());
    }
    for i in 0..n {
        for group in [&state.m[i], &state.v[i]] {
            if group.shape() != state.params[i].shape() {
                anyhow::bail!(
                    "moment shape {:?} != param shape {:?} at index {i}",
                    group.shape(),
                    state.params[i].shape()
                );
            }
        }
    }
    // Per-process temp name so concurrent savers can't interleave into one
    // temp file; fsync before the rename so a crash right after save()
    // can't persist the rename ahead of the data blocks.
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(format!(".{}.tmp", std::process::id()));
    let tmp = PathBuf::from(tmp_name);
    let f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
    let mut w = CrcWriter::new(BufWriter::new(f));
    let res = (|| -> Result<()> {
        write_body(&mut w, state, n)?;
        if let Some(meta) = opt {
            write_opt_section(&mut w, meta)?;
        }
        let file_crc = w.file_crc();
        w.write_raw(FOOTER_MAGIC)?;
        w.write_raw(&file_crc.to_le_bytes())?;
        w.flush()?;
        w.inner.get_ref().sync_all()?;
        Ok(())
    })();
    drop(w);
    if let Err(e) = res {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    // Keep the previous checkpoint as `.bak` (best-effort: a failure here
    // degrades recovery, not the save itself).
    if path.exists() {
        let _ = std::fs::rename(path, bak_path(path));
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} into place"))?;
    Ok(())
}

/// Sibling backup path kept by [`save`]: `foo.ckpt` → `foo.ckpt.bak`.
fn bak_path(path: &Path) -> PathBuf {
    let mut name = path.as_os_str().to_owned();
    name.push(".bak");
    PathBuf::from(name)
}

/// v3 payload after validation: header + n checksummed records.
fn write_body<W: Write>(w: &mut CrcWriter<W>, state: &ModelState, n: usize) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&state.step.to_le_bytes())?;
    write_str(w, &state.model)?;
    w.write_all(&(n as u32).to_le_bytes())?;
    for i in 0..n {
        w.begin_record();
        // Hand-built states may omit names; synthesize the same positional
        // fallback v1 migration uses so round-trips stay name-stable.
        match state.names.get(i) {
            Some(name) => write_str(w, name)?,
            None => write_str(w, &format!("param.{i}"))?,
        }
        let t = &state.params[i];
        w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for group in [&state.params[i], &state.m[i], &state.v[i]] {
            write_f32s(w, group.data())?;
        }
        let crc = w.end_record();
        // The stored record checksum is covered by the file checksum but
        // (by construction) not by its own record checksum.
        w.write_all(&crc.to_le_bytes())?;
    }
    Ok(())
}

/// Read a checkpoint (v3, or legacy v1/v2), ignoring any trailing
/// optimizer section.
pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
    Ok(load_with_optimizer(path)?.0)
}

/// [`load`], falling back to the `.bak` sibling kept by [`save`] when the
/// primary file is corrupt, truncated, or unreadable. Returns the state
/// plus `true` when the backup supplied it. Fails only when both copies
/// are unusable; the primary's typed error is surfaced, with the backup's
/// failure attached as context.
pub fn load_with_recovery(path: impl AsRef<Path>) -> Result<(ModelState, bool)> {
    let path = path.as_ref();
    let primary_err = match load(path) {
        Ok(state) => return Ok((state, false)),
        Err(e) => e,
    };
    match load(bak_path(path)) {
        Ok(state) => Ok((state, true)),
        Err(bak_err) => Err(primary_err.context(format!(
            "backup {:?} is also unusable: {bak_err:#}",
            bak_path(path)
        ))),
    }
}

/// [`load`] plus the optional optimizer section (`None` for files written
/// by plain [`save`] and for legacy v1 checkpoints).
pub fn load_with_optimizer(path: impl AsRef<Path>) -> Result<(ModelState, Option<OptimMeta>)> {
    let path = path.as_ref();
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    // Used to reject length fields that claim more data than the file
    // holds *before* sizing any allocation by them.
    let file_len = f.metadata().map(|m| m.len()).unwrap_or(u64::MAX);
    let mut r = HashingReader::new(BufReader::new(f));
    let mut magic = [0u8; 4];
    read_exact_ck(&mut r, &mut magic, "magic")?;
    if &magic != MAGIC {
        return Err(malformed("not a panther checkpoint (bad magic)"));
    }
    let version = read_u32(&mut r)?;
    let step = read_u64(&mut r)?;
    let model = read_str(&mut r, file_len)?;
    let n = read_u32(&mut r)? as usize;
    // Every record needs at least name-len + rank + record fields.
    ensure_claim(n as u128 * 8, file_len, "parameter record count")?;
    match version {
        1 => {
            let state = load_v1_body(&mut r, model, step, n, file_len)?;
            Ok((state, None))
        }
        2 => {
            let state = load_v2_body(&mut r, model, step, n, file_len)?;
            let opt = read_opt_section(&mut r, file_len)?;
            Ok((state, opt))
        }
        3 => {
            let state = load_v3_body(&mut r, model, step, n, file_len)?;
            let opt = read_v3_tail(&mut r, file_len)?;
            Ok((state, opt))
        }
        other => Err(malformed(format!("unsupported checkpoint version {other}"))),
    }
}

/// Trailing optimizer section: marker | kind | hyperparameter list.
fn write_opt_section(w: &mut impl Write, meta: &OptimMeta) -> Result<()> {
    w.write_all(OPT_MAGIC)?;
    write_str(w, &meta.kind)?;
    w.write_all(&(meta.hyper.len() as u32).to_le_bytes())?;
    write_f32s(w, &meta.hyper)?;
    Ok(())
}

/// Optimizer section payload after its marker.
fn read_opt_payload(r: &mut impl Read, file_len: u64) -> Result<OptimMeta> {
    let kind = read_str(r, file_len)?;
    let n = read_u32(r)? as usize;
    ensure_claim(n as u128 * 4, file_len, "optimizer hyperparameter count")?;
    let hyper = read_f32s(r, n)?;
    Ok(OptimMeta { kind, hyper })
}

/// Read the optional v2 optimizer section: clean EOF right after the
/// records means "no section" (files written by plain [`save`]); anything
/// else must be a complete, well-formed section.
fn read_opt_section(r: &mut impl Read, file_len: u64) -> Result<Option<OptimMeta>> {
    let mut marker = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let k = r
            .read(&mut marker[got..])
            .map_err(|e| truncated(format!("optimizer section marker: {e}")))?;
        if k == 0 {
            break;
        }
        got += k;
    }
    if got == 0 {
        return Ok(None);
    }
    if got != 4 || &marker != OPT_MAGIC {
        return Err(malformed(
            "trailing garbage after checkpoint records (expected optimizer section)",
        ));
    }
    Ok(Some(read_opt_payload(r, file_len)?))
}

/// v3 tail: optional optimizer section, then the mandatory whole-file
/// checksum footer, then clean EOF. The file checksum covers every byte
/// before the footer marker.
fn read_v3_tail<R: Read>(r: &mut HashingReader<R>, file_len: u64) -> Result<Option<OptimMeta>> {
    // Snapshot before the marker read: if the marker turns out to be the
    // footer, its bytes are excluded from the file checksum.
    let mut at_footer = r.file_crc();
    let mut marker = [0u8; 4];
    read_exact_ck(r, &mut marker, "optimizer section or footer marker")?;
    let opt = if &marker == OPT_MAGIC {
        let meta = read_opt_payload(r, file_len)?;
        at_footer = r.file_crc();
        read_exact_ck(r, &mut marker, "footer marker")?;
        if &marker != FOOTER_MAGIC {
            return Err(malformed("expected checksum footer after optimizer section"));
        }
        Some(meta)
    } else if &marker == FOOTER_MAGIC {
        None
    } else {
        return Err(malformed(
            "expected optimizer section or checksum footer after records",
        ));
    };
    let stored = read_u32(r)?;
    if stored != at_footer {
        return Err(anyhow::Error::new(CheckpointError::CorruptCheckpoint {
            record: "<file>".to_string(),
            expected: stored,
            actual: at_footer,
        }));
    }
    let mut b = [0u8; 1];
    let extra = r
        .read(&mut b)
        .map_err(|e| truncated(format!("after footer: {e}")))?;
    if extra != 0 {
        return Err(malformed("trailing garbage after checksum footer"));
    }
    Ok(opt)
}

/// v3 body: n records of name | shape | param | m | v | record CRC32.
fn load_v3_body<R: Read>(
    r: &mut HashingReader<R>,
    model: String,
    step: u64,
    n: usize,
    file_len: u64,
) -> Result<ModelState> {
    let mut names = Vec::with_capacity(n);
    let mut params = Vec::with_capacity(n);
    let mut m = Vec::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        r.begin_record();
        let name = read_str(r, file_len)?;
        let shape = read_shape(r, file_len)?;
        let count = element_count(&shape, file_len)?;
        let p = read_f32s(r, count)?;
        let mi = read_f32s(r, count)?;
        let vi = read_f32s(r, count)?;
        let actual = r.end_record();
        let stored = read_u32(r)?;
        if stored != actual {
            return Err(anyhow::Error::new(CheckpointError::CorruptCheckpoint {
                record: name,
                expected: stored,
                actual,
            }));
        }
        names.push(name);
        params.push(HostTensor::new(&shape, p));
        m.push(HostTensor::new(&shape, mi));
        v.push(HostTensor::new(&shape, vi));
    }
    Ok(ModelState {
        model,
        names,
        params,
        m,
        v,
        step,
    })
}

/// v2 body: n records of name | shape | param | m | v (no checksums).
fn load_v2_body(
    r: &mut impl Read,
    model: String,
    step: u64,
    n: usize,
    file_len: u64,
) -> Result<ModelState> {
    let mut names = Vec::with_capacity(n);
    let mut params = Vec::with_capacity(n);
    let mut m = Vec::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        names.push(read_str(r, file_len)?);
        let shape = read_shape(r, file_len)?;
        let count = element_count(&shape, file_len)?;
        params.push(HostTensor::new(&shape, read_f32s(r, count)?));
        m.push(HostTensor::new(&shape, read_f32s(r, count)?));
        v.push(HostTensor::new(&shape, read_f32s(r, count)?));
    }
    Ok(ModelState {
        model,
        names,
        params,
        m,
        v,
        step,
    })
}

/// Legacy v1 body: three groups (params, m, v) of shape-prefixed tensors,
/// no names.
fn load_v1_body(
    r: &mut impl Read,
    model: String,
    step: u64,
    n: usize,
    file_len: u64,
) -> Result<ModelState> {
    let mut groups = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let shape = read_shape(r, file_len)?;
            let count = element_count(&shape, file_len)?;
            tensors.push(HostTensor::new(&shape, read_f32s(r, count)?));
        }
        groups.push(tensors);
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let params = groups.pop().unwrap();
    Ok(ModelState {
        model,
        names: (0..n).map(|i| format!("param.{i}")).collect(),
        params,
        m,
        v,
        step,
    })
}

/// Reject a length field that claims more bytes than the whole file holds.
/// Classified as truncation: the data the field promises cannot exist.
/// Called *before* any allocation sized by the field, so a bit-flipped
/// length can never trigger a huge allocation.
fn ensure_claim(bytes_claimed: u128, file_len: u64, what: &str) -> Result<()> {
    if bytes_claimed > file_len as u128 {
        return Err(truncated(format!(
            "{what} claims {bytes_claimed} bytes but the file holds {file_len}"
        )));
    }
    Ok(())
}

/// Element count of a shape, with overflow-checked arithmetic and a
/// claim-vs-file-size bound (4 bytes per element, three tensors per
/// record would be 12 — the 4-byte bound is the allocation guard).
fn element_count(shape: &[usize], file_len: u64) -> Result<usize> {
    let mut count: u128 = 1;
    for &d in shape {
        count = count
            .checked_mul(d as u128)
            .ok_or_else(|| malformed("tensor element count overflows"))?;
    }
    let bytes = count
        .checked_mul(4)
        .ok_or_else(|| malformed("tensor byte count overflows"))?;
    ensure_claim(bytes, file_len, "tensor payload")?;
    Ok(count as usize)
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    let b = s.as_bytes();
    w.write_all(&(b.len() as u32).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn read_str(r: &mut impl Read, file_len: u64) -> Result<String> {
    let len = read_u32(r)? as usize;
    ensure_claim(len as u128, file_len, "string length")?;
    let mut b = vec![0u8; len];
    read_exact_ck(r, &mut b, "string payload")?;
    String::from_utf8(b).map_err(|_| malformed("bad utf8 string in checkpoint"))
}

fn read_shape(r: &mut impl Read, file_len: u64) -> Result<Vec<usize>> {
    let rank = read_u32(r)? as usize;
    ensure_claim(rank as u128 * 8, file_len, "tensor rank")?;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(r)? as usize);
    }
    Ok(shape)
}

/// f32s staged per bulk-IO call: 64 KiB — large enough to amortize the
/// write/read, small enough not to double the largest tensor's memory.
const IO_CHUNK: usize = 16 * 1024;

/// Bulk-serialize a tensor: whole little-endian chunks, one write each,
/// O(1) extra memory.
fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(IO_CHUNK.min(xs.len()) * 4);
    for chunk in xs.chunks(IO_CHUNK) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Bulk-deserialize `n` f32s: chunked reads + in-memory decode, O(1) extra
/// memory beyond the result. Callers bound `n` against the file size
/// (see [`element_count`]) before this allocates.
fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; IO_CHUNK.min(n.max(1)) * 4];
    let mut remaining = n;
    while remaining > 0 {
        let take = IO_CHUNK.min(remaining);
        let bytes = &mut buf[..take * 4];
        read_exact_ck(r, bytes, "tensor payload")?;
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        remaining -= take;
    }
    Ok(out)
}

/// `read_exact` with the failure typed as [`CheckpointError::Truncated`].
fn read_exact_ck(r: &mut impl Read, buf: &mut [u8], what: &str) -> Result<()> {
    r.read_exact(buf)
        .map_err(|e| truncated(format!("{what}: {e}")))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    read_exact_ck(r, &mut b, "u32 field")?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    read_exact_ck(r, &mut b, "u64 field")?;
    Ok(u64::from_le_bytes(b))
}

/// Writer that folds every written byte into a whole-file CRC32 and,
/// between [`CrcWriter::begin_record`] / [`CrcWriter::end_record`], into a
/// per-record CRC32. [`CrcWriter::write_raw`] bypasses both hashers for
/// the footer itself.
struct CrcWriter<W: Write> {
    inner: W,
    file: Crc32,
    record: Option<Crc32>,
}

impl<W: Write> CrcWriter<W> {
    fn new(inner: W) -> Self {
        CrcWriter {
            inner,
            file: Crc32::new(),
            record: None,
        }
    }

    fn begin_record(&mut self) {
        self.record = Some(Crc32::new());
    }

    fn end_record(&mut self) -> u32 {
        self.record
            .take()
            .expect("end_record without begin_record")
            .finish()
    }

    fn file_crc(&self) -> u32 {
        self.file.finish()
    }

    /// Write without hashing (the footer must not checksum itself).
    fn write_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.inner.write_all(bytes)
    }
}

impl<W: Write> Write for CrcWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        let k = self.inner.write(buf)?;
        self.file.update(&buf[..k]);
        if let Some(rec) = &mut self.record {
            rec.update(&buf[..k]);
        }
        Ok(k)
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

/// Reader twin of [`CrcWriter`]: folds every byte read into a whole-file
/// CRC32 and, between `begin_record`/`end_record`, into a per-record one.
struct HashingReader<R: Read> {
    inner: R,
    file: Crc32,
    record: Option<Crc32>,
}

impl<R: Read> HashingReader<R> {
    fn new(inner: R) -> Self {
        HashingReader {
            inner,
            file: Crc32::new(),
            record: None,
        }
    }

    fn begin_record(&mut self) {
        self.record = Some(Crc32::new());
    }

    fn end_record(&mut self) -> u32 {
        self.record
            .take()
            .expect("end_record without begin_record")
            .finish()
    }

    fn file_crc(&self) -> u32 {
        self.file.finish()
    }
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let k = self.inner.read(buf)?;
        self.file.update(&buf[..k]);
        if let Some(rec) = &mut self.record {
            rec.update(&buf[..k]);
        }
        Ok(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn toy_state() -> ModelState {
        let mut rng = Philox::seeded(3);
        let params = vec![
            HostTensor::randn(&[4, 3], 1.0, &mut rng),
            HostTensor::randn(&[7], 0.5, &mut rng),
            HostTensor::scalar(2.0),
        ];
        let m = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        let v = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        ModelState {
            model: "toy_model".into(),
            names: vec!["emb.w".into(), "head.b".into(), "temp".into()],
            params,
            m,
            v,
            step: 42,
        }
    }

    #[test]
    fn roundtrip() {
        let state = toy_state();
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, "toy_model");
        assert_eq!(back.step, 42);
        assert_eq!(back.names, state.names);
        assert_eq!(back.params.len(), 3);
        for (a, b) in back.params.iter().zip(&state.params) {
            assert_eq!(a, b);
        }
        assert_eq!(back.param_named("head.b"), Some(&state.params[1]));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn nameless_state_gets_positional_names() {
        let mut state = toy_state();
        state.names.clear();
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("nameless.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.names, vec!["param.0", "param.1", "param.2"]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn failed_save_preserves_existing_checkpoint() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("keep.ckpt");
        let good = toy_state();
        save(&good, &path).unwrap();
        // A state with a mismatched moment shape must fail validation
        // without touching the existing file.
        let mut bad = toy_state();
        bad.m[0] = HostTensor::zeros(&[1]);
        assert!(save(&bad, &path).is_err());
        let back = load(&path).unwrap();
        assert_eq!(back.params, good.params);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn optimizer_section_roundtrip_and_absence() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        // With a section: round-trips exactly.
        let path = dir.join("with_opt.ckpt");
        let meta = OptimMeta {
            kind: "adam".to_string(),
            hyper: vec![0.01, 0.9, 0.999, 1e-8, 42.0],
        };
        save_with_optimizer(&toy_state(), Some(&meta), &path).unwrap();
        let (state, back) = load_with_optimizer(&path).unwrap();
        assert_eq!(state.step, 42);
        assert_eq!(back, Some(meta));
        // Plain load ignores the section.
        assert_eq!(load(&path).unwrap().names, state.names);
        // Without a section: None, and plain save produces none.
        let path2 = dir.join("without_opt.ckpt");
        save(&toy_state(), &path2).unwrap();
        let (_, none) = load_with_optimizer(&path2).unwrap();
        assert_eq!(none, None);
        std::fs::remove_file(path).ok();
        std::fs::remove_file(path2).ok();
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trailing.ckpt");
        save(&toy_state(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"JUNK");
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_with_optimizer(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_unknown_version() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.ckpt");
        let mut blob: Vec<u8> = Vec::new();
        blob.extend_from_slice(b"PNTH");
        blob.extend_from_slice(&9u32.to_le_bytes());
        blob.extend_from_slice(&0u64.to_le_bytes());
        blob.extend_from_slice(&1u32.to_le_bytes());
        blob.push(b'x');
        blob.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &blob).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("version"), "got: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn record_payload_corruption_is_typed() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flip_record.ckpt");
        save(&toy_state(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Header is 33 bytes (magic 4 + version 4 + step 8 + model-name
        // 4+9 + n 4); record 0 payload (`emb.w`, [4,3]) starts at
        // 33 + 9 + 4 + 16 = 62. Byte 70 sits inside its param f32s.
        bytes[70] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        match err.downcast_ref::<CheckpointError>() {
            Some(CheckpointError::CorruptCheckpoint {
                record,
                expected,
                actual,
            }) => {
                assert_eq!(record, "emb.w");
                assert_ne!(expected, actual);
            }
            other => panic!("expected CorruptCheckpoint, got {other:?} ({err})"),
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn header_tampering_is_caught_by_the_file_footer() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("flip_header.ckpt");
        save(&toy_state(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Byte 8 is inside the step field: every record checksum still
        // passes, so only the whole-file footer can catch it.
        bytes[8] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err();
        match err.downcast_ref::<CheckpointError>() {
            Some(CheckpointError::CorruptCheckpoint { record, .. }) => {
                assert_eq!(record, "<file>");
            }
            other => panic!("expected file-footer CorruptCheckpoint, got {other:?} ({err})"),
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn truncation_is_typed() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("truncated.ckpt");
        save(&toy_state(), &path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = load(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<CheckpointError>(),
                Some(CheckpointError::Truncated { .. })
            ),
            "expected Truncated, got {err}"
        );
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn save_keeps_bak_and_recovery_falls_back() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("recover.ckpt");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(bak_path(&path)).ok();
        let first = toy_state();
        save(&first, &path).unwrap();
        let mut second = toy_state();
        second.step = 43;
        save(&second, &path).unwrap();
        // The previous save survives as `.bak`.
        assert_eq!(load(bak_path(&path)).unwrap().step, 42);
        // Healthy primary: no fallback.
        let (state, recovered) = load_with_recovery(&path).unwrap();
        assert_eq!((state.step, recovered), (43, false));
        // Corrupt primary: the backup answers, flagged.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 3]).unwrap();
        let (state, recovered) = load_with_recovery(&path).unwrap();
        assert_eq!((state.step, recovered), (42, true));
        for (a, b) in state.params.iter().zip(&first.params) {
            assert_eq!(a, b);
        }
        // Both unusable: the primary's typed error surfaces.
        std::fs::remove_file(bak_path(&path)).unwrap();
        let err = load_with_recovery(&path).unwrap_err();
        assert!(err.downcast_ref::<CheckpointError>().is_some(), "got {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn legacy_v2_files_still_load() {
        // Hand-written v2 bytes: one [2]-tensor named "w", no checksums,
        // no footer.
        let mut blob: Vec<u8> = Vec::new();
        blob.extend_from_slice(b"PNTH");
        blob.extend_from_slice(&2u32.to_le_bytes());
        blob.extend_from_slice(&7u64.to_le_bytes());
        blob.extend_from_slice(&3u32.to_le_bytes());
        blob.extend_from_slice(b"old");
        blob.extend_from_slice(&1u32.to_le_bytes());
        blob.extend_from_slice(&1u32.to_le_bytes());
        blob.push(b'w');
        blob.extend_from_slice(&1u32.to_le_bytes());
        blob.extend_from_slice(&2u64.to_le_bytes());
        for x in [1.5f32, -2.5, 0.0, 0.0, 0.0, 0.0] {
            blob.extend_from_slice(&x.to_le_bytes());
        }
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("legacy_v2.ckpt");
        std::fs::write(&path, &blob).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, "old");
        assert_eq!(back.step, 7);
        assert_eq!(back.names, vec!["w"]);
        assert_eq!(back.params[0].data(), &[1.5, -2.5]);
        std::fs::remove_file(path).ok();
    }
}
