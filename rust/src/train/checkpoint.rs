//! Checkpoints: serialize a [`super::ModelState`] to a simple binary file.
//!
//! Format (little-endian):
//! ```text
//! magic "PNTH" | version u32 | step u64 | model-name (u32 len + utf8)
//! | n_params u32 | 3 groups (params, m, v) × n tensors:
//!     rank u32 | dims u64 × rank | data f32 × prod(dims)
//! ```

use super::ModelState;
use crate::runtime::HostTensor;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PNTH";
const VERSION: u32 = 1;

/// Write a checkpoint.
pub fn save(state: &ModelState, path: impl AsRef<Path>) -> Result<()> {
    let f = std::fs::File::create(path.as_ref())
        .with_context(|| format!("creating {:?}", path.as_ref()))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&state.step.to_le_bytes())?;
    let name = state.model.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    w.write_all(&(state.params.len() as u32).to_le_bytes())?;
    for group in [&state.params, &state.m, &state.v] {
        for t in group.iter() {
            w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            for &x in t.data() {
                w.write_all(&x.to_le_bytes())?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a checkpoint.
pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a panther checkpoint (bad magic)");
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported checkpoint version {version}");
    }
    let step = read_u64(&mut r)?;
    let name_len = read_u32(&mut r)? as usize;
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let model = String::from_utf8(name).context("bad model name")?;
    let n = read_u32(&mut r)? as usize;
    let mut groups = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let rank = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut r)? as usize);
            }
            let count: usize = shape.iter().product();
            let mut data = vec![0f32; count];
            let mut buf = [0u8; 4];
            for x in &mut data {
                r.read_exact(&mut buf)?;
                *x = f32::from_le_bytes(buf);
            }
            tensors.push(HostTensor::new(&shape, data));
        }
        groups.push(tensors);
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let params = groups.pop().unwrap();
    Ok(ModelState {
        model,
        params,
        m,
        v,
        step,
    })
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn toy_state() -> ModelState {
        let mut rng = Philox::seeded(3);
        let params = vec![
            HostTensor::randn(&[4, 3], 1.0, &mut rng),
            HostTensor::randn(&[7], 0.5, &mut rng),
            HostTensor::scalar(2.0),
        ];
        let m = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        let v = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        ModelState {
            model: "toy_model".into(),
            params,
            m,
            v,
            step: 42,
        }
    }

    #[test]
    fn roundtrip() {
        let state = toy_state();
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, "toy_model");
        assert_eq!(back.step, 42);
        assert_eq!(back.params.len(), 3);
        for (a, b) in back.params.iter().zip(&state.params) {
            assert_eq!(a, b);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
