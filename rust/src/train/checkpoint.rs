//! Checkpoints: serialize a [`super::ModelState`] to a simple binary file.
//!
//! Current format — **v2**, name-keyed (little-endian):
//! ```text
//! magic "PNTH" | version u32 = 2 | step u64 | model-name (u32 len + utf8)
//! | n_params u32 | n records:
//!     param-name (u32 len + utf8) | rank u32 | dims u64 × rank
//!     | param f32 × prod(dims) | m f32 × prod(dims) | v f32 × prod(dims)
//! ```
//! Tensor payloads are bulk-serialized as little-endian byte chunks
//! (64 KiB staged per IO call — not one write per `f32`, and not a full
//! per-tensor buffer that would double the largest tensor's memory).
//!
//! Legacy **v1** files (positional, three groups of shape-prefixed
//! tensors) still load; their parameters get synthesized positional names
//! `param.{i}` since v1 never stored names.
//!
//! After the records, v2 files may carry an **optional optimizer
//! section**:
//! ```text
//! "OPTS" | kind (u32 len + utf8) | n_hyper u32 | hyper f32 × n_hyper
//! ```
//! written by [`save_with_optimizer`] (the native
//! [`super::Trainer`](super::trainer::Trainer) uses it to persist the
//! optimizer identity and scalar state; the moments themselves ride in the
//! per-record `m`/`v` slots). Readers that don't care ([`load`]) skip it;
//! files without it load as `None` — both directions stay compatible, so
//! the version stays 2.

use super::optimizer::OptimMeta;
use super::ModelState;
use crate::runtime::HostTensor;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"PNTH";
const VERSION: u32 = 2;
const OPT_MAGIC: &[u8; 4] = b"OPTS";

/// Write a checkpoint (always the current v2 format). The state is
/// validated up front and the bytes go to a sibling temp file that is
/// renamed into place only on success — a failed save never truncates an
/// existing checkpoint at `path`.
pub fn save(state: &ModelState, path: impl AsRef<Path>) -> Result<()> {
    save_with_optimizer(state, None, path)
}

/// [`save`] plus an optional trailing optimizer section carrying the
/// optimizer's identity and scalar state (Adam's step counter etc.).
pub fn save_with_optimizer(
    state: &ModelState,
    opt: Option<&OptimMeta>,
    path: impl AsRef<Path>,
) -> Result<()> {
    let path = path.as_ref();
    let n = state.params.len();
    ensure!(
        state.m.len() == n && state.v.len() == n,
        "param/moment arity mismatch: {n} params, {} m, {} v",
        state.m.len(),
        state.v.len()
    );
    ensure!(
        state.names.is_empty() || state.names.len() == n,
        "state has {} names for {n} params",
        state.names.len()
    );
    for i in 0..n {
        for group in [&state.m[i], &state.v[i]] {
            ensure!(
                group.shape() == state.params[i].shape(),
                "moment shape {:?} != param shape {:?} at index {i}",
                group.shape(),
                state.params[i].shape()
            );
        }
    }
    // Per-process temp name so concurrent savers can't interleave into one
    // temp file; fsync before the rename so a crash right after save()
    // can't persist the rename ahead of the data blocks.
    let mut tmp_name = path.as_os_str().to_owned();
    tmp_name.push(format!(".{}.tmp", std::process::id()));
    let tmp = std::path::PathBuf::from(tmp_name);
    let f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
    let mut w = BufWriter::new(f);
    let res = write_body(&mut w, state, n)
        .and_then(|_| match opt {
            Some(meta) => write_opt_section(&mut w, meta),
            None => Ok(()),
        })
        .and(w.flush().map_err(anyhow::Error::from))
        .and(w.get_ref().sync_all().map_err(anyhow::Error::from));
    drop(w);
    if let Err(e) = res {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} into place"))?;
    Ok(())
}

/// v2 payload after validation: header + n name/shape/param/m/v records.
fn write_body(w: &mut impl Write, state: &ModelState, n: usize) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&state.step.to_le_bytes())?;
    write_str(w, &state.model)?;
    w.write_all(&(n as u32).to_le_bytes())?;
    for i in 0..n {
        // Hand-built states may omit names; synthesize the same positional
        // fallback v1 migration uses so round-trips stay name-stable.
        match state.names.get(i) {
            Some(name) => write_str(w, name)?,
            None => write_str(w, &format!("param.{i}"))?,
        }
        let t = &state.params[i];
        w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        for group in [&state.params[i], &state.m[i], &state.v[i]] {
            write_f32s(w, group.data())?;
        }
    }
    Ok(())
}

/// Read a checkpoint (v2, or legacy v1 with synthesized names), ignoring
/// any trailing optimizer section.
pub fn load(path: impl AsRef<Path>) -> Result<ModelState> {
    Ok(load_with_optimizer(path)?.0)
}

/// [`load`] plus the optional optimizer section (`None` for files written
/// by plain [`save`] and for legacy v1 checkpoints).
pub fn load_with_optimizer(path: impl AsRef<Path>) -> Result<(ModelState, Option<OptimMeta>)> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {:?}", path.as_ref()))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("not a panther checkpoint (bad magic)");
    }
    let version = read_u32(&mut r)?;
    let step = read_u64(&mut r)?;
    let model = read_str(&mut r)?;
    let n = read_u32(&mut r)? as usize;
    let state = match version {
        1 => load_v1_body(&mut r, model, step, n)?,
        2 => load_v2_body(&mut r, model, step, n)?,
        other => bail!("unsupported checkpoint version {other}"),
    };
    let opt = if version >= 2 {
        read_opt_section(&mut r)?
    } else {
        None
    };
    Ok((state, opt))
}

/// Trailing optimizer section: marker | kind | hyperparameter list.
fn write_opt_section(w: &mut impl Write, meta: &OptimMeta) -> Result<()> {
    w.write_all(OPT_MAGIC)?;
    write_str(w, &meta.kind)?;
    w.write_all(&(meta.hyper.len() as u32).to_le_bytes())?;
    write_f32s(w, &meta.hyper)?;
    Ok(())
}

/// Read the optional optimizer section: clean EOF right after the records
/// means "no section" (files written by plain [`save`]); anything else
/// must be a complete, well-formed section.
fn read_opt_section(r: &mut impl Read) -> Result<Option<OptimMeta>> {
    let mut marker = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let k = r.read(&mut marker[got..])?;
        if k == 0 {
            break;
        }
        got += k;
    }
    if got == 0 {
        return Ok(None);
    }
    ensure!(
        got == 4 && &marker == OPT_MAGIC,
        "trailing garbage after checkpoint records (expected optimizer section)"
    );
    let kind = read_str(r)?;
    let n = read_u32(r)? as usize;
    let hyper = read_f32s(r, n)?;
    Ok(Some(OptimMeta { kind, hyper }))
}

/// v2 body: n records of name | shape | param | m | v.
fn load_v2_body(r: &mut impl Read, model: String, step: u64, n: usize) -> Result<ModelState> {
    let mut names = Vec::with_capacity(n);
    let mut params = Vec::with_capacity(n);
    let mut m = Vec::with_capacity(n);
    let mut v = Vec::with_capacity(n);
    for _ in 0..n {
        names.push(read_str(r)?);
        let shape = read_shape(r)?;
        let count: usize = shape.iter().product();
        params.push(HostTensor::new(&shape, read_f32s(r, count)?));
        m.push(HostTensor::new(&shape, read_f32s(r, count)?));
        v.push(HostTensor::new(&shape, read_f32s(r, count)?));
    }
    Ok(ModelState {
        model,
        names,
        params,
        m,
        v,
        step,
    })
}

/// Legacy v1 body: three groups (params, m, v) of shape-prefixed tensors,
/// no names.
fn load_v1_body(r: &mut impl Read, model: String, step: u64, n: usize) -> Result<ModelState> {
    let mut groups = Vec::with_capacity(3);
    for _ in 0..3 {
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let shape = read_shape(r)?;
            let count: usize = shape.iter().product();
            tensors.push(HostTensor::new(&shape, read_f32s(r, count)?));
        }
        groups.push(tensors);
    }
    let v = groups.pop().unwrap();
    let m = groups.pop().unwrap();
    let params = groups.pop().unwrap();
    Ok(ModelState {
        model,
        names: (0..n).map(|i| format!("param.{i}")).collect(),
        params,
        m,
        v,
        step,
    })
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    let b = s.as_bytes();
    w.write_all(&(b.len() as u32).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u32(r)? as usize;
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    String::from_utf8(b).context("bad utf8 string in checkpoint")
}

fn read_shape(r: &mut impl Read) -> Result<Vec<usize>> {
    let rank = read_u32(r)? as usize;
    let mut shape = Vec::with_capacity(rank);
    for _ in 0..rank {
        shape.push(read_u64(r)? as usize);
    }
    Ok(shape)
}

/// f32s staged per bulk-IO call: 64 KiB — large enough to amortize the
/// write/read, small enough not to double the largest tensor's memory.
const IO_CHUNK: usize = 16 * 1024;

/// Bulk-serialize a tensor: whole little-endian chunks, one write each,
/// O(1) extra memory.
fn write_f32s(w: &mut impl Write, xs: &[f32]) -> Result<()> {
    let mut buf = Vec::with_capacity(IO_CHUNK.min(xs.len()) * 4);
    for chunk in xs.chunks(IO_CHUNK) {
        buf.clear();
        for &x in chunk {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    Ok(())
}

/// Bulk-deserialize `n` f32s: chunked reads + in-memory decode, O(1) extra
/// memory beyond the result.
fn read_f32s(r: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(n);
    let mut buf = vec![0u8; IO_CHUNK.min(n.max(1)) * 4];
    let mut remaining = n;
    while remaining > 0 {
        let take = IO_CHUNK.min(remaining);
        let bytes = &mut buf[..take * 4];
        r.read_exact(bytes)?;
        out.extend(
            bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        remaining -= take;
    }
    Ok(out)
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    fn toy_state() -> ModelState {
        let mut rng = Philox::seeded(3);
        let params = vec![
            HostTensor::randn(&[4, 3], 1.0, &mut rng),
            HostTensor::randn(&[7], 0.5, &mut rng),
            HostTensor::scalar(2.0),
        ];
        let m = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        let v = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
        ModelState {
            model: "toy_model".into(),
            names: vec!["emb.w".into(), "head.b".into(), "temp".into()],
            params,
            m,
            v,
            step: 42,
        }
    }

    #[test]
    fn roundtrip() {
        let state = toy_state();
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("toy.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, "toy_model");
        assert_eq!(back.step, 42);
        assert_eq!(back.names, state.names);
        assert_eq!(back.params.len(), 3);
        for (a, b) in back.params.iter().zip(&state.params) {
            assert_eq!(a, b);
        }
        assert_eq!(back.param_named("head.b"), Some(&state.params[1]));
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn nameless_state_gets_positional_names() {
        let mut state = toy_state();
        state.names.clear();
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("nameless.ckpt");
        save(&state, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.names, vec!["param.0", "param.1", "param.2"]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn failed_save_preserves_existing_checkpoint() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("keep.ckpt");
        let good = toy_state();
        save(&good, &path).unwrap();
        // A state with a mismatched moment shape must fail validation
        // without touching the existing file.
        let mut bad = toy_state();
        bad.m[0] = HostTensor::zeros(&[1]);
        assert!(save(&bad, &path).is_err());
        let back = load(&path).unwrap();
        assert_eq!(back.params, good.params);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn optimizer_section_roundtrip_and_absence() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        // With a section: round-trips exactly.
        let path = dir.join("with_opt.ckpt");
        let meta = OptimMeta {
            kind: "adam".to_string(),
            hyper: vec![0.01, 0.9, 0.999, 1e-8, 42.0],
        };
        save_with_optimizer(&toy_state(), Some(&meta), &path).unwrap();
        let (state, back) = load_with_optimizer(&path).unwrap();
        assert_eq!(state.step, 42);
        assert_eq!(back, Some(meta));
        // Plain load ignores the section.
        assert_eq!(load(&path).unwrap().names, state.names);
        // Without a section: None, and plain save produces none.
        let path2 = dir.join("without_opt.ckpt");
        save(&toy_state(), &path2).unwrap();
        let (_, none) = load_with_optimizer(&path2).unwrap();
        assert_eq!(none, None);
        std::fs::remove_file(path).ok();
        std::fs::remove_file(path2).ok();
    }

    #[test]
    fn trailing_garbage_is_rejected() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trailing.ckpt");
        save(&toy_state(), &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"JUNK");
        std::fs::write(&path, &bytes).unwrap();
        assert!(load_with_optimizer(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_unknown_version() {
        let dir = std::env::temp_dir().join("panther_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("future.ckpt");
        let mut blob: Vec<u8> = Vec::new();
        blob.extend_from_slice(b"PNTH");
        blob.extend_from_slice(&9u32.to_le_bytes());
        blob.extend_from_slice(&0u64.to_le_bytes());
        blob.extend_from_slice(&1u32.to_le_bytes());
        blob.push(b'x');
        blob.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &blob).unwrap();
        let err = load(&path).unwrap_err();
        assert!(err.to_string().contains("version"), "got: {err}");
        std::fs::remove_file(path).ok();
    }
}
