//! Learning-rate schedules over any [`Optimizer`] (ROADMAP item).
//!
//! [`ScheduledOpt`] wraps an inner optimizer and, before every step, sets
//! its learning rate to `base_lr · schedule.factor(t)` — warmup ramps,
//! cosine decay, and stepwise drops compose with SGD and Adam without
//! either side knowing about the other. The wrapper's scalar state (the
//! schedule's shape, the base rate, and the step counter) rides in the
//! checkpoint's optimizer section next to the inner optimizer's own
//! scalars, so a resumed fine-tune continues the schedule *exactly* —
//! the same resume-bit-exactness contract the plain optimizers already
//! honor (u64 counters are bit-pattern-encoded, never `as f32` rounded).

use super::optimizer::{OptimMeta, Optimizer};
use crate::nn::{Model, StateDict};
use crate::runtime::HostTensor;
use anyhow::{bail, ensure, Result};

/// Encode a u64 counter as two exact f32 bit patterns (the same trick
/// Adam's step counter uses — `as f32` would round past 2²⁴).
pub(crate) fn u64_to_f32s(v: u64) -> [f32; 2] {
    [f32::from_bits(v as u32), f32::from_bits((v >> 32) as u32)]
}

/// Inverse of [`u64_to_f32s`].
pub(crate) fn f32s_to_u64(lo: f32, hi: f32) -> u64 {
    lo.to_bits() as u64 | ((hi.to_bits() as u64) << 32)
}

/// The learning-rate multiplier curve. `factor(t)` is applied to the base
/// rate before step `t` (0-indexed: the first `Optimizer::step` sees
/// `factor(0)`).
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// `factor = 1` — a transparent wrapper (useful to thread the
    /// schedule machinery through code paths unconditionally).
    Constant,
    /// Linear ramp `(t+1)/steps` over the first `steps` steps, then 1.
    Warmup { steps: u64 },
    /// Linear warmup to 1, then cosine decay to `floor` at `total` steps
    /// (and `floor` beyond) — the standard fine-tuning schedule.
    WarmupCosine { warmup: u64, total: u64, floor: f32 },
    /// `gamma^(t / every)` — multiplicative drop every `every` steps.
    Step { every: u64, gamma: f32 },
}

impl LrSchedule {
    /// The multiplier on the base learning rate at step `t` (0-indexed).
    pub fn factor(&self, t: u64) -> f32 {
        match *self {
            LrSchedule::Constant => 1.0,
            LrSchedule::Warmup { steps } => {
                if steps == 0 || t >= steps {
                    1.0
                } else {
                    (t + 1) as f32 / steps as f32
                }
            }
            LrSchedule::WarmupCosine {
                warmup,
                total,
                floor,
            } => {
                if warmup > 0 && t < warmup {
                    return (t + 1) as f32 / warmup as f32;
                }
                let span = total.saturating_sub(warmup).max(1);
                let p = ((t - warmup) as f64 / span as f64).min(1.0);
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * p).cos());
                floor + (1.0 - floor) * cos as f32
            }
            LrSchedule::Step { every, gamma } => {
                let k = if every == 0 { 0 } else { t / every };
                gamma.powi(k.min(i32::MAX as u64) as i32)
            }
        }
    }

    /// Schedule → scalar list for the checkpoint's optimizer section:
    /// a kind tag, then the shape parameters (u64s bit-encoded).
    fn encode(&self) -> Vec<f32> {
        match *self {
            LrSchedule::Constant => vec![0.0],
            LrSchedule::Warmup { steps } => {
                let s = u64_to_f32s(steps);
                vec![1.0, s[0], s[1]]
            }
            LrSchedule::WarmupCosine {
                warmup,
                total,
                floor,
            } => {
                let w = u64_to_f32s(warmup);
                let n = u64_to_f32s(total);
                vec![2.0, w[0], w[1], n[0], n[1], floor]
            }
            LrSchedule::Step { every, gamma } => {
                let e = u64_to_f32s(every);
                vec![3.0, e[0], e[1], gamma]
            }
        }
    }

    /// Inverse of [`LrSchedule::encode`]: parse a schedule off the front
    /// of `hyper`, returning it and the scalars consumed.
    fn decode(hyper: &[f32]) -> Result<(LrSchedule, usize)> {
        ensure!(!hyper.is_empty(), "empty schedule section");
        match hyper[0] as i64 {
            0 => Ok((LrSchedule::Constant, 1)),
            1 => {
                ensure!(hyper.len() >= 3, "warmup schedule wants 2 scalars");
                Ok((
                    LrSchedule::Warmup {
                        steps: f32s_to_u64(hyper[1], hyper[2]),
                    },
                    3,
                ))
            }
            2 => {
                ensure!(hyper.len() >= 6, "cosine schedule wants 5 scalars");
                Ok((
                    LrSchedule::WarmupCosine {
                        warmup: f32s_to_u64(hyper[1], hyper[2]),
                        total: f32s_to_u64(hyper[3], hyper[4]),
                        floor: hyper[5],
                    },
                    6,
                ))
            }
            3 => {
                ensure!(hyper.len() >= 4, "step schedule wants 3 scalars");
                Ok((
                    LrSchedule::Step {
                        every: f32s_to_u64(hyper[1], hyper[2]),
                        gamma: hyper[3],
                    },
                    4,
                ))
            }
            other => bail!("unknown LR schedule tag {other} in checkpoint"),
        }
    }
}

/// An [`Optimizer`] that drives its inner optimizer's learning rate along
/// an [`LrSchedule`]. The base rate is captured from the inner optimizer
/// at construction; the wrapper owns the schedule step counter (which
/// counts *its own* steps, so a wrapper added mid-run starts its curve at
/// the hand-off).
pub struct ScheduledOpt {
    inner: Box<dyn Optimizer>,
    schedule: LrSchedule,
    base_lr: f32,
    /// Scheduled steps taken.
    t: u64,
}

impl ScheduledOpt {
    pub fn new(inner: Box<dyn Optimizer>, schedule: LrSchedule) -> Self {
        let base_lr = inner.lr();
        ScheduledOpt {
            inner,
            schedule,
            base_lr,
            t: 0,
        }
    }

    /// Rebuild from the checkpoint scalars (see [`ScheduledOpt::meta`]).
    pub(crate) fn from_meta_parts(inner_kind: &str, hyper: &[f32]) -> Result<Self> {
        let (schedule, used) = LrSchedule::decode(hyper)?;
        ensure!(
            hyper.len() >= used + 3,
            "scheduled-optimizer section truncated"
        );
        let base_lr = hyper[used];
        let t = f32s_to_u64(hyper[used + 1], hyper[used + 2]);
        let inner_meta = OptimMeta {
            kind: inner_kind.to_string(),
            hyper: hyper[used + 3..].to_vec(),
        };
        let inner = super::optimizer::optimizer_from_meta(&inner_meta)?;
        Ok(ScheduledOpt {
            inner,
            schedule,
            base_lr,
            t,
        })
    }

    /// The learning rate the *next* step will run at.
    pub fn current_lr(&self) -> f32 {
        self.base_lr * self.schedule.factor(self.t)
    }

    /// Scheduled steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// The wrapped schedule.
    pub fn schedule(&self) -> &LrSchedule {
        &self.schedule
    }
}

impl Optimizer for ScheduledOpt {
    fn step(&mut self, model: &mut Model) -> Result<()> {
        let lr = self.base_lr * self.schedule.factor(self.t);
        self.inner.set_lr(lr);
        // Count the step only once the inner update succeeded — a failed
        // step must not consume a point on the schedule curve (a retry
        // should see the same factor).
        self.inner.step(model)?;
        self.t += 1;
        Ok(())
    }

    /// `kind = "sched:<inner kind>"`, `hyper = schedule shape ‖ base_lr ‖
    /// t (bit-encoded) ‖ inner hyper` — one flat scalar list, because the
    /// checkpoint optimizer section is a kind plus f32s by design.
    fn meta(&self) -> OptimMeta {
        let inner = self.inner.meta();
        let mut hyper = self.schedule.encode();
        hyper.push(self.base_lr);
        hyper.extend(u64_to_f32s(self.t));
        hyper.extend(inner.hyper);
        OptimMeta {
            kind: format!("sched:{}", inner.kind),
            hyper,
        }
    }

    /// The rate the next step will actually apply (base × factor) — the
    /// trait's "current learning rate" contract, not the base rate.
    fn lr(&self) -> f32 {
        self.current_lr()
    }

    /// Re-bases the schedule: the curve keeps its shape around the new
    /// base rate.
    fn set_lr(&mut self, lr: f32) {
        self.base_lr = lr;
    }

    fn export_moments(&self, sd: &StateDict) -> (Vec<HostTensor>, Vec<HostTensor>) {
        self.inner.export_moments(sd)
    }

    fn import_moments(
        &mut self,
        names: &[String],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<()> {
        self.inner.import_moments(names, m, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{ForwardCtx, Linear, Model};
    use crate::rng::Philox;
    use crate::train::optimizer::{optimizer_from_meta, Adam, Sgd};
    use crate::train::Trainer;

    #[test]
    fn factor_curves() {
        let w = LrSchedule::Warmup { steps: 4 };
        assert_eq!(w.factor(0), 0.25);
        assert_eq!(w.factor(3), 1.0);
        assert_eq!(w.factor(100), 1.0);
        let c = LrSchedule::WarmupCosine {
            warmup: 2,
            total: 10,
            floor: 0.1,
        };
        assert_eq!(c.factor(0), 0.5);
        assert_eq!(c.factor(1), 1.0);
        // Right after warmup: cosine starts at 1.
        assert!((c.factor(2) - 1.0).abs() < 1e-6);
        // Midpoint of the decay span (t−warmup = 4 of 8): halfway down.
        assert!((c.factor(6) - 0.55).abs() < 1e-6, "{}", c.factor(6));
        // End and beyond: pinned at the floor.
        assert!((c.factor(10) - 0.1).abs() < 1e-6);
        assert!((c.factor(1000) - 0.1).abs() < 1e-6);
        let s = LrSchedule::Step {
            every: 3,
            gamma: 0.5,
        };
        assert_eq!(s.factor(0), 1.0);
        assert_eq!(s.factor(2), 1.0);
        assert_eq!(s.factor(3), 0.5);
        assert_eq!(s.factor(8), 0.25);
        assert_eq!(LrSchedule::Constant.factor(7), 1.0);
    }

    #[test]
    fn scheduled_sgd_applies_the_curve_exactly() {
        // One 1-parameter-ish model: watch the actual update magnitudes.
        let mut rng = Philox::seeded(31);
        let mut model = Model::new();
        model.add("fc", Linear::random(2, 1, &mut rng)).unwrap();
        let ctx = ForwardCtx::new();
        let x = Mat::filled(1, 2, 1.0);
        let y = Mat::filled(1, 1, 10.0);
        let opt = ScheduledOpt::new(Box::new(Sgd::new(0.1)), LrSchedule::Warmup { steps: 2 });
        assert_eq!(opt.current_lr(), 0.05, "first step ramps at 1/2");
        let mut tr = Trainer::new(Box::new(opt));
        // Step 1 at lr 0.05, step 2 at 0.1: gradients differ, but the
        // per-step weight delta must equal lr·grad for the scheduled lr.
        for expect_lr in [0.05f32, 0.1, 0.1] {
            let before = model.state_dict();
            tr.train_step(&mut model, &x, &y, &ctx).unwrap();
            let after = model.state_dict();
            let grad: Vec<f32> = model
                .get("fc")
                .unwrap()
                .grads()
                .into_iter()
                .flat_map(|(_, g)| g.to_vec())
                .collect();
            let delta: Vec<f32> = before
                .iter()
                .zip(&after)
                .flat_map(|((_, b), (_, a))| {
                    b.data()
                        .iter()
                        .zip(a.data())
                        .map(|(&bv, &av)| bv - av)
                        .collect::<Vec<f32>>()
                })
                .collect();
            for (d, g) in delta.iter().zip(&grad) {
                assert!(
                    (d - expect_lr * g).abs() <= 1e-6 * g.abs().max(1.0),
                    "delta {d} vs lr·grad {}",
                    expect_lr * g
                );
            }
        }
    }

    #[test]
    fn meta_roundtrips_all_schedules_exactly() {
        for sched in [
            LrSchedule::Constant,
            LrSchedule::Warmup { steps: 1000 },
            LrSchedule::WarmupCosine {
                warmup: (1 << 33) + 7, // exercises the bit encoding
                total: (1 << 34) + 11,
                floor: 0.05,
            },
            LrSchedule::Step {
                every: 250,
                gamma: 0.3,
            },
        ] {
            let mut opt = ScheduledOpt::new(Box::new(Adam::new(0.02)), sched.clone());
            opt.t = 12_345;
            let meta = opt.meta();
            assert!(meta.kind.starts_with("sched:adam"), "{}", meta.kind);
            let back = optimizer_from_meta(&meta).unwrap();
            assert_eq!(back.meta(), meta, "roundtrip for {sched:?}");
        }
        // Unknown inner kind and bad tag both fail loudly.
        assert!(optimizer_from_meta(&OptimMeta {
            kind: "sched:lion".into(),
            hyper: vec![0.0, 0.1, 0.0, 0.0],
        })
        .is_err());
        assert!(optimizer_from_meta(&OptimMeta {
            kind: "sched:sgd".into(),
            hyper: vec![9.0],
        })
        .is_err());
    }

    #[test]
    fn scheduled_checkpoint_resumes_mid_warmup_exactly() {
        // Save mid-warmup, resume, and require bit-equal loss curves —
        // the schedule counter and base rate must survive the round-trip.
        let build = || {
            let mut rng = Philox::seeded(33);
            let mut m = Model::new();
            m.add("fc1", Linear::random(6, 10, &mut rng)).unwrap();
            m.add("fc2", Linear::random(10, 4, &mut rng)).unwrap();
            m
        };
        let (x, y) = {
            let mut rng = Philox::seeded(34);
            let x = Mat::randn(16, 6, &mut rng);
            let teacher = Linear::random(6, 4, &mut rng);
            let y = teacher.forward(&x);
            (x, y)
        };
        let ctx = ForwardCtx::new();
        let sched = LrSchedule::WarmupCosine {
            warmup: 6,
            total: 20,
            floor: 0.1,
        };
        let mut model = build();
        let mut tr = Trainer::new(Box::new(ScheduledOpt::new(
            Box::new(Adam::new(0.01)),
            sched,
        )));
        for _ in 0..4 {
            tr.train_step(&mut model, &x, &y, &ctx).unwrap();
        }
        let dir = std::env::temp_dir().join("panther_sched_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("warmup.ckpt");
        tr.save_checkpoint(&model, "sched", &path).unwrap();

        // Branch A: keep going in memory.
        let mut model_a = model.clone_model();
        let mut tr_a = tr;
        let losses_a: Vec<f32> = (0..6)
            .map(|_| tr_a.train_step(&mut model_a, &x, &y, &ctx).unwrap())
            .collect();
        // Branch B: resume from disk into a fresh architecture.
        let mut model_b = build();
        let mut tr_b = Trainer::resume(&mut model_b, &path).unwrap();
        assert_eq!(tr_b.step, 4);
        let losses_b: Vec<f32> = (0..6)
            .map(|_| tr_b.train_step(&mut model_b, &x, &y, &ctx).unwrap())
            .collect();
        assert_eq!(losses_a, losses_b, "schedule must resume exactly");
        std::fs::remove_file(&path).ok();
    }
}
