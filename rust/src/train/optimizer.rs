//! Optimizers over the native `nn` layer stack: named parameter/gradient
//! pairs, not positional tensor lists.
//!
//! After [`crate::nn::Model::backward`] has accumulated gradients inside
//! every layer, an [`Optimizer`] walks the registry and applies one update
//! per named parameter (`<layer path>.<param name>` keys Adam's moments, so
//! swapping a layer via `SketchPlan` simply starts fresh moments for the
//! new parameter names). Updates go through `params_mut` followed by
//! `on_params_loaded`, so layers with parameter-derived state stay
//! consistent — the same contract every other parameter writer follows.

use crate::nn::{Model, StateDict};
use crate::runtime::HostTensor;
use anyhow::{bail, ensure, Result};
use std::collections::HashMap;

/// Serializable optimizer identity + scalar state, stored in the optional
/// optimizer section of a checkpoint (see [`super::checkpoint`]): the
/// `kind` tag plus a flat list of hyperparameters/counters whose meaning
/// is private to the optimizer. Tensor state (Adam's moments) rides in the
/// checkpoint's per-parameter `m`/`v` slots instead.
#[derive(Debug, Clone, PartialEq)]
pub struct OptimMeta {
    pub kind: String,
    pub hyper: Vec<f32>,
}

/// An optimizer over every named (parameter, gradient) pair of a
/// [`Model`]. Implementations must key any per-parameter state by the
/// full dotted name so layer replacement and checkpoint resume compose.
pub trait Optimizer: Send {
    /// Apply one update from the gradients currently accumulated in
    /// `model` (a no-op for layers whose gradients were never touched).
    /// Does not zero gradients — the trainer owns that.
    fn step(&mut self, model: &mut Model) -> Result<()>;

    /// Identity + scalar state for checkpointing.
    fn meta(&self) -> OptimMeta;

    /// The learning rate the next [`Optimizer::step`] will apply (for a
    /// schedule-wrapped optimizer this is the *scheduled* rate, not the
    /// base).
    fn lr(&self) -> f32;

    /// Set the learning rate — the hook
    /// [`super::schedule::ScheduledOpt`] drives before every step.
    /// Stateful optimizers keep their accumulated state (Adam's moments
    /// and counter are untouched); only the step size changes. On a
    /// schedule wrapper this re-bases the curve.
    fn set_lr(&mut self, lr: f32);

    /// Per-parameter moment tensors for `sd`'s names/shapes, in order —
    /// zeros for names this optimizer has no state for (and for stateless
    /// optimizers entirely). Feeds the checkpoint's `m`/`v` slots.
    fn export_moments(&self, sd: &StateDict) -> (Vec<HostTensor>, Vec<HostTensor>);

    /// Restore per-parameter moments (inverse of
    /// [`Optimizer::export_moments`]).
    fn import_moments(
        &mut self,
        names: &[String],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<()>;
}

/// Rebuild an optimizer from its checkpointed [`OptimMeta`].
pub fn optimizer_from_meta(meta: &OptimMeta) -> Result<Box<dyn Optimizer>> {
    if let Some(inner_kind) = meta.kind.strip_prefix("sched:") {
        let sched = super::schedule::ScheduledOpt::from_meta_parts(inner_kind, &meta.hyper)?;
        return Ok(Box::new(sched));
    }
    match meta.kind.as_str() {
        "sgd" => {
            ensure!(meta.hyper.len() == 1, "sgd meta wants [lr]");
            Ok(Box::new(Sgd::new(meta.hyper[0])))
        }
        "adam" => {
            ensure!(
                meta.hyper.len() == 6,
                "adam meta wants [lr, b1, b2, eps, t_lo, t_hi]"
            );
            let mut adam = Adam::new(meta.hyper[0]);
            adam.beta1 = meta.hyper[1];
            adam.beta2 = meta.hyper[2];
            adam.eps = meta.hyper[3];
            // The u64 step counter rides the f32 list as two raw bit
            // patterns (an `as f32` cast would lose exactness past 2^24,
            // breaking the resume-exactly contract on long fine-tunes).
            adam.t = meta.hyper[4].to_bits() as u64 | ((meta.hyper[5].to_bits() as u64) << 32);
            Ok(Box::new(adam))
        }
        other => bail!("unknown optimizer kind {other:?} in checkpoint"),
    }
}

/// Collect each layer's gradients into owned per-name update buffers, then
/// write `param -= f(name, grad)` through `params_mut` and refresh derived
/// state. Shared by both optimizers — only `f` differs.
fn apply_updates(
    model: &mut Model,
    mut update: impl FnMut(&str, &[f32]) -> Vec<f32>,
) -> Result<()> {
    for layer in model.iter_mut() {
        let lname = layer.name.clone();
        let updates: Vec<(String, Vec<f32>)> = layer
            .module
            .grads()
            .into_iter()
            .map(|(pname, g)| {
                let full = format!("{lname}.{pname}");
                (pname, update(&full, g))
            })
            .collect();
        if updates.is_empty() {
            continue;
        }
        for (pname, mut p) in layer.module.params_mut() {
            if let Some((_, u)) = updates.iter().find(|(n, _)| *n == pname) {
                let data = p.data_mut();
                ensure!(
                    data.len() == u.len(),
                    "gradient length {} != parameter length {} for {lname}.{pname}",
                    u.len(),
                    data.len()
                );
                for (pv, &uv) in data.iter_mut().zip(u) {
                    *pv -= uv;
                }
            }
        }
        layer.module.on_params_loaded();
    }
    Ok(())
}

/// Plain stochastic gradient descent: `θ ← θ − lr·g`. Stateless — resume
/// only needs the learning rate.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
}

impl Sgd {
    pub fn new(lr: f32) -> Self {
        Sgd { lr }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, model: &mut Model) -> Result<()> {
        let lr = self.lr;
        apply_updates(model, |_, g| g.iter().map(|&x| lr * x).collect())
    }

    fn meta(&self) -> OptimMeta {
        OptimMeta {
            kind: "sgd".to_string(),
            hyper: vec![self.lr],
        }
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn export_moments(&self, sd: &StateDict) -> (Vec<HostTensor>, Vec<HostTensor>) {
        let zeros: Vec<HostTensor> = sd.iter().map(|(_, t)| HostTensor::zeros(t.shape())).collect();
        (zeros.clone(), zeros)
    }

    fn import_moments(
        &mut self,
        _names: &[String],
        _m: &[HostTensor],
        _v: &[HostTensor],
    ) -> Result<()> {
        Ok(())
    }
}

/// Adam (Kingma & Ba 2015) with bias correction. First/second moments are
/// keyed by the full dotted parameter name; the step counter `t` is part
/// of the persisted scalar state so a resumed fine-tune continues the
/// bias-correction schedule exactly.
#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Steps taken (drives bias correction).
    pub t: u64,
    m: HashMap<String, Vec<f32>>,
    v: HashMap<String, Vec<f32>>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: HashMap::new(),
            v: HashMap::new(),
        }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, model: &mut Model) -> Result<()> {
        self.t += 1;
        let (b1, b2, eps, lr) = (self.beta1, self.beta2, self.eps, self.lr);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        let (ms, vs) = (&mut self.m, &mut self.v);
        apply_updates(model, |full, g| {
            let m = ms
                .entry(full.to_string())
                .or_insert_with(|| vec![0.0; g.len()]);
            let v = vs
                .entry(full.to_string())
                .or_insert_with(|| vec![0.0; g.len()]);
            let mut u = Vec::with_capacity(g.len());
            for i in 0..g.len() {
                m[i] = b1 * m[i] + (1.0 - b1) * g[i];
                v[i] = b2 * v[i] + (1.0 - b2) * g[i] * g[i];
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                u.push(lr * mhat / (vhat.sqrt() + eps));
            }
            u
        })
    }

    fn meta(&self) -> OptimMeta {
        // t is stored as two raw f32 bit patterns (see
        // [`optimizer_from_meta`]) — the checkpoint serializes hyper
        // values byte-exactly, so this round-trips any u64.
        OptimMeta {
            kind: "adam".to_string(),
            hyper: vec![
                self.lr,
                self.beta1,
                self.beta2,
                self.eps,
                f32::from_bits(self.t as u32),
                f32::from_bits((self.t >> 32) as u32),
            ],
        }
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn export_moments(&self, sd: &StateDict) -> (Vec<HostTensor>, Vec<HostTensor>) {
        let pick = |map: &HashMap<String, Vec<f32>>| -> Vec<HostTensor> {
            sd.iter()
                .map(|(name, t)| match map.get(name) {
                    Some(buf) if buf.len() == t.len() => HostTensor::new(t.shape(), buf.clone()),
                    _ => HostTensor::zeros(t.shape()),
                })
                .collect()
        };
        (pick(&self.m), pick(&self.v))
    }

    fn import_moments(
        &mut self,
        names: &[String],
        m: &[HostTensor],
        v: &[HostTensor],
    ) -> Result<()> {
        ensure!(
            names.len() == m.len() && names.len() == v.len(),
            "moment arity mismatch: {} names, {} m, {} v",
            names.len(),
            m.len(),
            v.len()
        );
        for (i, name) in names.iter().enumerate() {
            ensure!(
                m[i].shape() == v[i].shape(),
                "m/v shape mismatch for {name}"
            );
            self.m.insert(name.clone(), m[i].data().to_vec());
            self.v.insert(name.clone(), v[i].data().to_vec());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_meta_roundtrips_large_step_counters_exactly() {
        // Past 2^24 an `as f32` cast would round; the bit-pattern encoding
        // must not.
        // NB: compare through the bit encoding, not f32 equality (NaN bit
        // patterns compare unequal as floats). Counters stay far below
        // the range whose high word would encode as a NaN (~9e18 steps).
        for t in [0u64, 1, 42, (1 << 24) + 1, (1 << 33) + 12_345] {
            let mut adam = Adam::new(0.01);
            adam.t = t;
            let meta = adam.meta();
            let back = optimizer_from_meta(&meta).unwrap();
            let meta2 = back.meta();
            assert_eq!(
                meta2.hyper[4].to_bits() as u64 | ((meta2.hyper[5].to_bits() as u64) << 32),
                t
            );
            assert_eq!(meta.hyper[..4], meta2.hyper[..4]);
        }
    }

    #[test]
    fn sgd_meta_roundtrip_and_unknown_kind_rejected() {
        let sgd = Sgd::new(0.25);
        let back = optimizer_from_meta(&sgd.meta()).unwrap();
        assert_eq!(back.meta(), sgd.meta());
        let bad = OptimMeta {
            kind: "lion".to_string(),
            hyper: vec![],
        };
        assert!(optimizer_from_meta(&bad).is_err());
    }
}
